"""Unified telemetry subsystem (DESIGN.md §13): per-request span tracing,
the metric registry + exposition, and the adapters that bind serving,
pager, mutation, and autotune state into them.

House invariant, extended to observability: tracing is read-only —
search results with tracing enabled are BIT-IDENTICAL to tracing off
(single-runtime, sharded, and paged-store continuous serving), and a
disabled tracer costs one attribute lookup on the hot path.

The acceptance bar from the issue: a traced degraded run (one shard
crashing + pager I/O errors) must produce a span tree whose union of
phase intervals attributes >=95% of each traced request's wall-clock.
"""
import json
import sys
from collections import Counter
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (EngineOptions, SearchConfig, build_engine,
                        mlp_measure)
from repro.core.corpus import ResidencyPolicy, make_corpus_store
from repro.core.sharded import build_sharded_index
from repro.graph import DurableIndex, build_l2_graph
from repro.kernels import autotune
from repro.obs import (NULL_TRACER, NullTracer, Registry, Tracer,
                       attribution, format_trace)
from repro.serving import (ContinuousRuntime, FaultEvent, FaultPlan,
                           ServingMetrics, ShardedContinuousRuntime)
from repro.serving.metrics import RequestRecord


@pytest.fixture(scope="module")
def system():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(600, 16)).astype(np.float32)
    queries = rng.normal(size=(24, 16)).astype(np.float32)
    graph = build_l2_graph(base, m=8, k_construction=24)
    measure = mlp_measure(jax.random.PRNGKey(1), 16, 16, hidden=(32,))
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    engine = build_engine(measure, cfg,
                          EngineOptions(rank_impl="ref", measure_impl="vmap"))
    sharded = build_sharded_index(base, n_shards=2, m=8, k_construction=24)
    return dict(base=base, queries=queries, graph=graph, measure=measure,
                cfg=cfg, engine=engine, sharded=sharded)


def _run_single(s, tracer=NULL_TRACER, corpus=None, n=12):
    rt = ContinuousRuntime(s["engine"], s["measure"].params,
                           s["base"] if corpus is None else corpus,
                           s["graph"].neighbors, n_lanes=4, query_dim=16,
                           entry=s["graph"].entry, steps_per_tick=2,
                           tracer=tracer)
    for i in range(n):
        rt.submit(s["queries"][i], rid=i)
    while rt.queue or rt.in_flight:
        rt.step_once()
    return {c.rid: c for c in rt.pop_completions()}, rt


def _drive_sharded(rt, queries, per_round=2):
    i, out = 0, {}
    while i < len(queries) or rt.in_flight or rt.queued or rt._partial \
            or any(r.completions for r in rt.runtimes):
        for _ in range(per_round):
            if i < len(queries):
                rt.submit(queries[i], rid=i)
                i += 1
        for c in rt.step_once():
            out[c.rid] = c
    return out


# ---------------------------------------------------------------------------
# tracer mechanics
# ---------------------------------------------------------------------------

def test_ring_buffer_wraparound():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.emit(f"s{i}", 0.0, 1.0)
    spans = tr.spans()
    assert len(spans) == 4                      # bounded
    assert [s.name for s in spans] == ["s6", "s7", "s8", "s9"]  # oldest out
    assert tr.n_emitted == 10                   # lifetime counter survives


def test_drain_force_closes_open_spans():
    tr = Tracer()
    sid = tr.begin("tick", rid=3)
    tr.root_for(3, t0=0.0)
    done = tr.end(tr.begin("admit", rid=3))
    assert not done.open
    drained = tr.drain()
    assert {s.name for s in drained} == {"tick", "request"}
    assert all(s.open for s in drained)         # flagged, not silently lost
    assert tr.end(sid) is None                  # already force-closed
    # roots cleared: a new root_for starts a fresh request span
    assert tr.root_for(3) != drained[0].span_id
    tr.drain()


def test_sampling_is_pure_function_of_rid():
    tr = Tracer(sample=4)
    assert tr.sampled(0) and tr.sampled(8)
    assert not tr.sampled(1) and not tr.sampled(6)
    assert not tr.sampled(-1)                   # warmup sentinel
    assert not tr.sampled(None)
    with pytest.raises(ValueError):
        Tracer(sample=0)
    with pytest.raises(ValueError):
        Tracer(capacity=0)


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert isinstance(NULL_TRACER, NullTracer)
    assert NULL_TRACER.begin("x") == -1
    assert NULL_TRACER.emit("x", 0, 1) == -1
    assert NULL_TRACER.sampled(0) is False
    assert NULL_TRACER.drain() == [] and NULL_TRACER.spans() == []


def test_export_jsonl_round_trip(tmp_path):
    tr = Tracer()
    tr.emit("tick", 0.0, 0.002, rid=0, site="shard:1", i=3)
    tr.emit("page_fault", 0.001, 0.0015, site="pager", pid=7)
    path = str(tmp_path / "traces.jsonl")
    assert tr.export_jsonl(path) == 2
    recs = [json.loads(line) for line in open(path)]
    assert [r["name"] for r in recs] == ["tick", "page_fault"]
    assert recs[0]["rid"] == 0 and recs[0]["attrs"] == {"i": 3}
    assert recs[1]["site"] == "pager"


def test_attribution_and_format_trace_edge_cases():
    att = attribution([], rid=0)
    assert att == {"wall_ms": 0.0, "attributed_ms": 0.0, "coverage": 0.0,
                   "by_name": {}}
    assert format_trace([], rid=3) == "(no trace for rid=3)"
    # overlapping leaves count once in coverage, per-name sums stay raw
    tr = Tracer()
    tr.root_for(0, t0=0.0)
    tr.emit("tick", 0.0, 0.6, rid=0)
    tr.emit("tick", 0.4, 1.0, rid=0)
    tr.finish_request(0, t1=1.0)
    att = attribution(tr.spans(), 0)
    assert att["coverage"] == pytest.approx(1.0)
    assert att["by_name"]["tick"] == pytest.approx(1200.0)  # 0.6s + 0.6s
    txt = format_trace(tr, 0)
    assert txt.startswith("request rid=0") and "tick" in txt


# ---------------------------------------------------------------------------
# registry mechanics + exposition
# ---------------------------------------------------------------------------

def test_registry_label_cardinality_cap():
    reg = Registry(max_series_per_metric=2)
    c = reg.counter("repro_test_total", labelnames=("status",))
    c.labels(status="ok").inc()
    c.labels(status="shed").inc()
    with pytest.raises(ValueError, match="cardinality"):
        c.labels(status="a-third-value")
    c.labels(status="ok").inc()                 # existing series still fine
    with pytest.raises(ValueError):             # undeclared label name
        c.labels(shard="0")


def test_registry_name_and_kind_validation():
    reg = Registry()
    with pytest.raises(ValueError):
        reg.counter("9starts_with_digit")
    with pytest.raises(ValueError):
        reg.counter("repro_x_total", labelnames=("bad-label",))
    reg.counter("repro_dup")
    with pytest.raises(ValueError, match="re-registered"):
        reg.gauge("repro_dup")
    with pytest.raises(ValueError):
        reg.counter("repro_neg").inc(-1)
    with pytest.raises(ValueError):
        reg.gauge("repro_g").observe(1.0)


def test_histogram_exposition_is_cumulative_and_monotone():
    reg = Registry()
    h = reg.histogram("repro_lat_ms", "t", buckets=(1.0, 10.0, 100.0))
    for v in (0.5, 0.7, 5.0, 50.0, 5000.0):
        h.observe(v)
    text = reg.render_text()
    counts = [int(line.rsplit(" ", 1)[1]) for line in text.splitlines()
              if line.startswith("repro_lat_ms_bucket")]
    assert counts == [2, 3, 4, 5]               # cumulative, +Inf == count
    assert counts == sorted(counts)
    assert "repro_lat_ms_count 5" in text
    j = reg.render_json()
    assert j["repro_lat_ms"]["series"][0]["count"] == 5


def test_registry_collect_callbacks_feed_gauges():
    reg = Registry()
    g = reg.gauge("repro_depth")
    state = {"depth": 0}
    reg.register_collect(lambda: g.set(state["depth"]))
    state["depth"] = 7
    assert "repro_depth 7" in reg.render_text()
    state["depth"] = 3
    assert json.loads(reg.render_json_str())[
        "repro_depth"]["series"][0]["value"] == 3.0


# ---------------------------------------------------------------------------
# satellites: ServingMetrics surface + adapters
# ---------------------------------------------------------------------------

def test_summary_surfaces_queue_depth_last():
    m = ServingMetrics(4)
    m.observe_queue_depth(5)
    m.observe_queue_depth(2)
    s = m.summary()
    assert s["queue_depth_last"] == 2.0 and s["queue_depth_max"] == 5.0


def test_report_is_clean_with_zero_completions():
    m = ServingMetrics(4)
    m.observe(RequestRecord(0, 0.0, 0.0, 0.0, shed=True))
    m.observe(RequestRecord(1, 0.0, 0.0, 0.1, timed_out=True))
    m.observe_queue_depth(3)
    line = m.report()
    assert "nan" not in line.lower()
    assert "completed=0" in line and "shed=1" in line
    assert "timed_out=1" in line


def test_serving_metrics_bind_registry():
    m = ServingMetrics(2)
    reg = m.bind_registry(Registry())
    m.observe(RequestRecord(0, 0.0, 0.001, 0.004, n_eval=30, n_iters=6))
    m.observe(RequestRecord(1, 0.0, 0.0, 0.0, shed=True))
    m.observe_queue_depth(4)
    m.observe_occupancy(busy=1, n_lanes=2)
    text = reg.render_text()
    assert 'repro_serving_requests_total{status="ok"} 1' in text
    assert 'repro_serving_requests_total{status="shed"} 1' in text
    assert "repro_serving_latency_ms_count 1" in text
    assert "repro_engine_evals_total 30" in text
    assert "repro_serving_queue_depth 4" in text
    assert "repro_serving_occupancy 0.5" in text
    # snapshot API unaffected by the registry view
    assert m.summary()["n_completed"] == 1.0


def test_autotune_bind_registry():
    reg = Registry()
    autotune.bind_registry(reg)
    before = dict(autotune.CACHE_STATS)
    autotune.CACHE_STATS["lookup_hits"] = before["lookup_hits"] + 2
    try:
        text = reg.render_text()
        want = autotune.CACHE_STATS["lookup_hits"]
        assert f"repro_autotune_lookup_hits_total {want}" in text
    finally:
        autotune.CACHE_STATS.update(before)


# ---------------------------------------------------------------------------
# pager + mutation span emission
# ---------------------------------------------------------------------------

def _paged(base, **policy_kw):
    policy = ResidencyPolicy("paged", page_rows=64, cache_bytes=1 << 20,
                             retry_backoff_s=0.0, **policy_kw)
    return make_corpus_store(base, "float32", residency=policy)


def test_pager_emits_fault_and_retry_spans(system):
    store = _paged(system["base"])
    tr = Tracer()
    store.set_tracer(tr)
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=1,
                                 count=2)])
    store.set_read_hook(plan.pager_hook())
    store.take(np.array([[0, 70, 130], [599, 3, 64]]))
    faults = tr.spans(rid=None, site="pager")
    assert any(s.name == "page_fault" and not s.attrs.get("failed")
               for s in faults)
    # retries absorbed the injected errors; the span still records them
    assert sum(s.attrs.get("io_errors", 0) for s in faults) == 2
    assert not any(s.attrs.get("failed") for s in faults)


def test_pager_fallback_emits_span(system):
    store = _paged(system["base"])
    tr = Tracer()
    store.set_tracer(tr)
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=0,
                                 count=10 ** 6)])
    store.set_read_hook(plan.pager_hook())
    store.take(np.arange(0, 600, 7))
    fb = [s for s in tr.spans(site="pager") if s.name == "fallback"]
    assert len(fb) == 1 and fb[0].attrs["rows"] == 600
    # the exhausted page fault before the fallback is flagged failed
    assert any(s.name == "page_fault" and s.attrs.get("failed")
               for s in tr.spans(site="pager"))


def test_pager_bind_registry(system):
    store = _paged(system["base"])
    reg = Registry()
    store.bind_registry(reg, shard="3")
    store.take(np.arange(0, 600, 11))
    text = reg.render_text()
    st = store.stats_snapshot()
    assert f'repro_pager_faults_total{{shard="3"}} {st.faults}' in text
    assert f'repro_pager_resident_bytes{{shard="3"}}' in text


def test_durable_index_emits_commit_spans(tmp_path):
    rng = np.random.default_rng(5)
    base = rng.normal(size=(80, 8)).astype(np.float32)
    graph = build_l2_graph(base, m=4, k_construction=12)
    d = DurableIndex.create(str(tmp_path), graph)
    tr = Tracer()
    d.tracer = tr
    d.insert(rng.normal(size=(4, 8)).astype(np.float32), k_candidates=16)
    d.delete([3, 17])
    d.checkpoint()
    spans = tr.spans(rid=None, site="mutate")
    names = Counter(s.name for s in spans)
    assert names["commit"] == 2 and names["journal"] == 2
    assert names["checkpoint"] == 1
    ops = {s.attrs.get("op") for s in spans if s.name == "commit"}
    assert ops == {"insert", "delete"}
    for s in spans:                             # journal nests under commit
        if s.name == "journal":
            assert s.t1 <= max(x.t1 for x in spans if x.name == "commit")


# ---------------------------------------------------------------------------
# runtime integration: bit-identity, sampling, coverage
# ---------------------------------------------------------------------------

def test_single_runtime_bit_identical_traced_vs_untraced(system):
    ref, _ = _run_single(system)
    tr = Tracer(sample=1)
    got, _ = _run_single(system, tracer=tr)
    assert set(got) == set(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid].ids, ref[rid].ids)
        np.testing.assert_array_equal(got[rid].scores, ref[rid].scores)
        assert got[rid].status == ref[rid].status
    # every request produced a closed root + phase spans
    for rid in ref:
        names = {s.name for s in tr.spans(rid=rid)}
        assert {"request", "queue", "tick", "harvest"} <= names


def test_paged_continuous_bit_identical_traced(system):
    ref, _ = _run_single(system)
    tr = Tracer(sample=1)
    store = _paged(system["base"])
    store.set_tracer(tr)
    got, _ = _run_single(system, tracer=tr, corpus=store)
    for rid in ref:
        np.testing.assert_array_equal(got[rid].ids, ref[rid].ids)
        np.testing.assert_array_equal(got[rid].scores, ref[rid].scores)
    assert any(s.name == "page_fault" for s in tr.spans(site="pager"))


def test_sharded_bit_identical_traced_vs_untraced(system):
    s = system
    qs = s["queries"]

    def make(tracer):
        return ShardedContinuousRuntime(
            s["engine"], s["measure"].params, s["sharded"], n_lanes=4,
            query_dim=16, steps_per_tick=2, tracer=tracer)

    ref = _drive_sharded(make(NULL_TRACER), qs)
    tr = Tracer(sample=1)
    got = _drive_sharded(make(tr), qs)
    assert set(got) == set(ref)
    for rid in ref:
        np.testing.assert_array_equal(got[rid].ids, ref[rid].ids)
        np.testing.assert_array_equal(got[rid].scores, ref[rid].scores)
    # fan-out spans carry the shard site; the merge layer owns the root
    sites = {sp.site for sp in tr.spans(rid=0)}
    assert {"shard:0", "shard:1"} <= sites
    assert any(sp.name == "merge" for sp in tr.spans(rid=0))


def test_sampled_out_requests_emit_zero_spans(system):
    tr = Tracer(sample=2)
    _run_single(system, tracer=tr)
    for rid in range(12):
        spans = tr.spans(rid=rid)
        if rid % 2 == 0:
            assert spans, f"rid {rid} sampled but traceless"
        else:
            assert spans == [], f"rid {rid} sampled out but has spans"


def test_healthy_run_attribution_covers_wall_clock(system):
    tr = Tracer(sample=1)
    _run_single(system, tracer=tr)
    att = attribution(tr.spans(), 0)
    assert att["wall_ms"] > 0
    assert att["coverage"] >= 0.95
    assert {"queue", "tick", "harvest"} <= set(att["by_name"])


def test_runtime_bind_registry_exposes_serving_series(system):
    tr = Tracer(sample=1)
    rt = ContinuousRuntime(system["engine"], system["measure"].params,
                           system["base"], system["graph"].neighbors,
                           n_lanes=4, query_dim=16,
                           entry=system["graph"].entry, steps_per_tick=2,
                           tracer=tr)
    reg = Registry()
    rt.bind_registry(reg)
    for i in range(8):
        rt.submit(system["queries"][i], rid=i)
    while rt.queue or rt.in_flight:
        rt.step_once()
    text = reg.render_text()
    assert 'repro_serving_requests_total{status="ok"} 8' in text
    assert "repro_serving_latency_ms_count 8" in text
    rt.close()                                  # drains open spans
    assert all(not sp.open or sp.name == "request"
               for sp in tr.spans())


# ---------------------------------------------------------------------------
# the acceptance bar: traced degraded run attributes the wall-clock
# ---------------------------------------------------------------------------

def test_degraded_run_trace_attributes_latency(system):
    """Chaos plan (one shard's ticks crash until its breaker opens) plus
    transient pager I/O errors on the other shard's paged store: the
    traced span tree must still attribute >=95% of every traced answered
    request's end-to-end latency across queue/phase/merge (+ pager)
    spans — the issue's acceptance criterion."""
    s = system
    qs = np.random.default_rng(3).normal(size=(32, 16)).astype(np.float32)
    plan = FaultPlan([FaultEvent("shard_crash", site="shard:1/tick",
                                 start=3, count=3)], seed=0)
    tr = Tracer(sample=2, capacity=8192)
    rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=4,
        query_dim=16, steps_per_tick=2, k_failures=2, cooldown_rounds=3,
        fault_plan=plan, tracer=tr)
    # shard 0 serves from a paged store with a lossy (but transient,
    # retry-absorbed) read path, so pager spans weave into the traces
    paged = _paged(np.asarray(s["sharded"].base[0]))
    paged.set_tracer(tr)
    pager_plan = FaultPlan([FaultEvent("page_io_error", site="pager",
                                       start=0, count=60, rate=0.4)], seed=1)
    paged.set_read_hook(pager_plan.pager_hook())
    rt.runtimes[0].store = paged

    got = _drive_sharded(rt, qs)
    assert set(got) == set(range(32))           # every rid resolved
    statuses = Counter(c.status for c in got.values())
    assert statuses["partial"] > 0              # the crash really degraded

    spans = tr.spans()
    assert any(sp.name == "page_fault" for sp in spans)   # pager visible
    checked = 0
    for rid, c in got.items():
        if rid % 2 or c.status not in ("ok", "partial"):
            continue
        att = attribution(spans, rid, sites=("pager",))
        assert att["wall_ms"] > 0
        assert att["coverage"] >= 0.95, \
            f"rid {rid} ({c.status}): coverage {att['coverage']:.3f}"
        checked += 1
    assert checked >= 8
    # a degraded request's flame renders with its merge + phase spans
    rid = next(r for r, c in got.items()
               if r % 2 == 0 and c.status == "partial")
    txt = format_trace(tr, rid, sites=("pager",))
    assert txt.startswith(f"request rid={rid}")
    assert "merge" in txt and "@shard:" in txt
