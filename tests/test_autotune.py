"""Tile-autotuning tests (DESIGN.md §8): override-spec parsing, the cache
round-trip contract (second sweep is skipped; shipped defaults never
suppress one), lookup precedence, shipped-defaults coverage, wide-kernel
bt>1 parity on non-divisible shapes, and the engine-level
tile/rowwise/unfused fp32 bit-match."""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineOptions, SearchConfig, deepfm_measure,
                        make_corpus_store, search_measure)
from repro.graph import build_l2_graph
from repro.kernels import autotune
from repro.kernels.autotune import TileConfig
from repro.models import deepfm as deepfm_lib
from repro.models import layers as L


@pytest.fixture()
def tmp_cache(tmp_path, monkeypatch):
    """Point the local tuning cache at a throwaway file so tests never read
    or write the repo-local .tuning_cache.json."""
    path = tmp_path / "tuning.json"
    monkeypatch.setenv("REPRO_TUNING_CACHE", str(path))
    return path


# ---------------------------------------------------------------------------
# override-spec parsing
# ---------------------------------------------------------------------------

def test_parse_tile_specs():
    assert autotune.parse_tile(None) is None
    assert autotune.parse_tile("") is None
    assert autotune.parse_tile("tile") == TileConfig(plan="tile", bt=0)
    assert autotune.parse_tile("rowwise") == TileConfig(plan="rowwise", bt=0)
    assert autotune.parse_tile(":16") == TileConfig(plan="", bt=16)
    assert autotune.parse_tile("tile:4") == TileConfig(plan="tile", bt=4)
    for bad in ("diag", "tile:0", "tile:-3", "tile:x"):
        with pytest.raises(ValueError):
            autotune.parse_tile(bad)


def test_parse_tile_merges_over_base():
    base = TileConfig(plan="rowwise", bt=8)
    assert autotune.parse_tile(":16").merged_over(base) == \
        TileConfig(plan="rowwise", bt=16)
    assert autotune.parse_tile("tile").merged_over(base) == \
        TileConfig(plan="tile", bt=8)
    assert autotune.parse_tile("tile:4").merged_over(base) == \
        TileConfig(plan="tile", bt=4)


# ---------------------------------------------------------------------------
# lookup precedence + shipped defaults
# ---------------------------------------------------------------------------

def test_resolve_precedence(tmp_cache, monkeypatch):
    """override > local exact > shipped exact > local wildcard > shipped
    wildcard > builtin."""
    shape = dict(q=7, m=13, d=24, dtype="float32")
    monkeypatch.setattr(autotune, "shipped_defaults", lambda: {
        autotune.make_key("engine_step", 7, 13, 24, "float32"):
            {"plan": "rowwise", "bt": 2},
        autotune._wildcard("engine_step", None): {"plan": "tile", "bt": 3},
    })
    # nothing local: shipped exact beats shipped wildcard
    assert autotune.resolve("engine_step", **shape) == \
        TileConfig(plan="rowwise", bt=2)
    # local wildcard loses to shipped exact...
    wild = autotune._wildcard("engine_step", None)
    autotune.save_cache({wild: {"plan": "tile", "bt": 5}})
    assert autotune.resolve("engine_step", **shape) == \
        TileConfig(plan="rowwise", bt=2)
    # ...but wins where only wildcards match
    assert autotune.resolve("engine_step", q=1, m=1, d=1) == \
        TileConfig(plan="tile", bt=5)
    # local exact beats everything except the override
    autotune.record("engine_step", TileConfig(plan="tile", bt=16), **shape)
    assert autotune.resolve("engine_step", **shape) == \
        TileConfig(plan="tile", bt=16)
    # override merges field-wise on top of the winner
    assert autotune.resolve("engine_step", **shape,
                            override=autotune.parse_tile("rowwise")) == \
        TileConfig(plan="rowwise", bt=16)
    assert autotune.resolve("engine_step", **shape,
                            override=autotune.parse_tile(":4")) == \
        TileConfig(plan="tile", bt=4)
    # builtin fallback when nothing matches anywhere
    monkeypatch.setattr(autotune, "shipped_defaults", lambda: {})
    autotune.save_cache({})
    assert autotune.resolve("engine_step", **shape) == TileConfig()


def test_shipped_defaults_cover_cpu_kernels(tmp_cache):
    """Every tunable kernel ships a cpu wildcard so a fresh checkout never
    falls through to the builtin, and the engine-step CPU plan is tile."""
    shipped = autotune.shipped_defaults()
    for kernel in autotune.TUNABLE_KERNELS:
        assert f"cpu|{kernel}|*" in shipped, kernel
    # local cache is empty (tmp_cache) → lookup resolves via shipped
    cfg = autotune.lookup("engine_step", q=999, m=999, d=999, backend="cpu")
    assert cfg is not None and cfg.plan == "tile"


# ---------------------------------------------------------------------------
# round-trip: the second sweep is free
# ---------------------------------------------------------------------------

def test_autotune_round_trip_skips_second_sweep(tmp_cache):
    calls = []

    def bench(cand):
        calls.append(cand)
        return 0.001 if cand.plan == "tile" else 0.002

    cands = [TileConfig(plan="rowwise", bt=8), TileConfig(plan="tile", bt=8)]
    shape = dict(q=16, m=8, d=32, dtype="float32")
    won = autotune.autotune("engine_step", cands, bench, **shape)
    assert won.plan == "tile" and len(calls) == 2
    # second run: exact key is in the LOCAL cache → bench never called
    again = autotune.autotune("engine_step", cands, bench, **shape)
    assert again == won and len(calls) == 2
    # a different shape is a different key → sweeps
    autotune.autotune("engine_step", cands, bench, q=99, m=8, d=32)
    assert len(calls) == 4
    # force re-measures even on a hit
    autotune.autotune("engine_step", cands, bench, force=True, **shape)
    assert len(calls) == 6
    # the persisted entry carries the sweep evidence
    doc = json.loads(tmp_cache.read_text())
    entry = doc["entries"][autotune.make_key("engine_step", 16, 8, 32,
                                             "float32")]
    assert entry["plan"] == "tile" and "swept_us" in entry
    assert set(entry["swept_us"]) == {"rowwise:8", "tile:8"}


def test_shipped_defaults_do_not_suppress_sweep(tmp_cache, monkeypatch):
    """A shipped exact key must NOT short-circuit a requested sweep — only
    locally measured results do."""
    key = autotune.make_key("engine_step", 4, 4, 4, "float32")
    monkeypatch.setattr(autotune, "shipped_defaults",
                        lambda: {key: {"plan": "rowwise", "bt": 8}})
    calls = []

    def bench(cand):
        calls.append(cand)
        return 0.001

    autotune.autotune("engine_step", [TileConfig(plan="tile", bt=8)], bench,
                      q=4, m=4, d=4)
    assert len(calls) == 1


# ---------------------------------------------------------------------------
# wide-block kernels: bt > 1 parity on non-divisible shapes (interpret)
# ---------------------------------------------------------------------------

def test_wide_score_kernels_bt_parity(rng):
    """bt=1 and a non-divisible bt=5 (M=37) agree with the jnp fused ref
    for both score kernels, fp32 and int8 residency."""
    from repro.kernels.deepfm_score_fused import deepfm_score_fused
    from repro.kernels.mlp_score.ops import mlp_score_fused
    D, fm, M = 24, 8, 37
    base = rng.normal(size=(120, D)).astype(np.float32)
    ids = jnp.asarray(rng.integers(0, 120, size=(M,)).astype(np.int32))
    query = jnp.asarray(rng.normal(size=(M, D)).astype(np.float32))
    dfm, _ = L.init_mlp(jax.random.PRNGKey(0), [2 * (D - fm), 16, 16, 1],
                        jnp.float32)
    mlp, _ = L.init_mlp(jax.random.PRNGKey(1), [2 * D, 16, 1], jnp.float32)
    for dtype in ("float32", "int8"):
        store = make_corpus_store(base, dtype)
        ref = deepfm_score_fused(store, ids, query, dfm, fm,
                                 use_pallas=False)
        for spec in (":1", ":5"):
            out = deepfm_score_fused(store, ids, query, dfm, fm,
                                     use_pallas=True, interpret=True,
                                     tile=spec)
            np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                       rtol=1e-5, atol=1e-6)
        ref_m = mlp_score_fused(store, ids, query, mlp, use_pallas=False)
        for spec in (":1", ":5"):
            out_m = mlp_score_fused(store, ids, query, mlp, use_pallas=True,
                                    interpret=True, tile=spec)
            np.testing.assert_allclose(np.asarray(out_m), np.asarray(ref_m),
                                       rtol=1e-5, atol=1e-6)


def test_wide_grad_and_rank_kernels_bt_parity(rng):
    """Same bt sweep for the grad trios (vals, grads, dequantized frontier
    rows) and the fused ranker on a B not divisible by bt."""
    from repro.kernels.deepfm_grad_fused import deepfm_grad_fused
    from repro.kernels.mlp_grad.ops import mlp_grad_fused
    from repro.kernels.neighbor_rank_fused import neighbor_rank_fused
    D, fm, Q, B = 24, 8, 7, 9
    base = rng.normal(size=(90, D)).astype(np.float32)
    store = make_corpus_store(base, "float32")
    fid = jnp.asarray(rng.integers(0, 90, size=(Q,)).astype(np.int32))
    qrows = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    dfm, _ = L.init_mlp(jax.random.PRNGKey(0), [2 * (D - fm), 16, 16, 1],
                        jnp.float32)
    mlp, _ = L.init_mlp(jax.random.PRNGKey(1), [2 * D, 16, 1], jnp.float32)
    for fused, params, extra in ((deepfm_grad_fused, dfm, (fm,)),
                                 (mlp_grad_fused, mlp, ())):
        refs = fused(store, fid, qrows, params, *extra, use_pallas=False)
        for spec in (":1", ":4"):
            outs = fused(store, fid, qrows, params, *extra, use_pallas=True,
                         interpret=True, tile=spec)
            for o, r in zip(outs, refs):
                np.testing.assert_allclose(np.asarray(o), np.asarray(r),
                                           rtol=1e-5, atol=1e-5)
    x = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    g = jnp.asarray(rng.normal(size=(Q, D)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 90, size=(Q, B)).astype(np.int32))
    valid = jnp.asarray(rng.random((Q, B)) < 0.8).at[:, 0].set(True)
    k_ref, m_ref = neighbor_rank_fused(x, g, store, idx, valid, 1.2,
                                       "angle", use_pallas=False)
    fin = np.isfinite(np.asarray(k_ref))
    for spec in (":1", ":4"):
        k_p, m_p = neighbor_rank_fused(x, g, store, idx, valid, 1.2,
                                       "angle", use_pallas=True,
                                       interpret=True, tile=spec)
        np.testing.assert_allclose(np.asarray(k_p)[fin],
                                   np.asarray(k_ref)[fin],
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(m_p), np.asarray(m_ref))


# ---------------------------------------------------------------------------
# engine level: every plan is the same fp32 float program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["guitar", "sl2g"])
def test_engine_tile_plan_bit_matches_rowwise_and_unfused(mode):
    """EngineOptions(tile=...) picks a dataflow, never a result: tile,
    rowwise, and unfused fp32 searches are ids-AND-scores bit-identical."""
    cfg_m = deepfm_lib.DeepFMConfig()
    params, _ = deepfm_lib.init_measure(jax.random.PRNGKey(0), cfg_m)
    measure = deepfm_measure(params, cfg_m)
    rng = np.random.default_rng(5)
    base = rng.normal(size=(400, cfg_m.vec_dim)).astype(np.float32) * 0.5
    queries = rng.normal(size=(6, cfg_m.vec_dim)).astype(np.float32) * 0.5
    graph = build_l2_graph(base, m=10, k_construction=32)
    args = (jnp.asarray(base), jnp.asarray(graph.neighbors),
            jnp.asarray(queries), jnp.full((6,), graph.entry, jnp.int32))
    cfg = SearchConfig(k=10, ef=32, mode=mode, budget=6, alpha=1.1)
    r_un = search_measure(measure, *args, cfg, EngineOptions())
    r_row = search_measure(measure, *args, cfg,
                           EngineOptions(fused=True, tile="rowwise"))
    r_tile = search_measure(measure, *args, cfg,
                            EngineOptions(fused=True, tile="tile"))
    for r in (r_row, r_tile):
        np.testing.assert_array_equal(np.asarray(r_un.ids),
                                      np.asarray(r.ids))
        np.testing.assert_array_equal(np.asarray(r_un.scores),
                                      np.asarray(r.scores))
        np.testing.assert_array_equal(np.asarray(r_un.n_eval),
                                      np.asarray(r.n_eval))


# ---------------------------------------------------------------------------
# cache hardening: corrupt / malformed caches degrade to shipped defaults
# ---------------------------------------------------------------------------

def test_corrupt_cache_warns_and_falls_back(tmp_cache, monkeypatch):
    """A cache file that exists but won't parse (truncated write,
    hand-editing) must not crash plan resolution: one RuntimeWarning, then
    lookup falls through to the shipped defaults."""
    monkeypatch.setattr(autotune, "shipped_defaults", lambda: {
        "cpu|engine_step|*": {"plan": "tile", "bt": 8}})
    tmp_cache.write_text("{ this is not json")
    with pytest.warns(RuntimeWarning, match="corrupt"):
        cfg = autotune.lookup("engine_step", backend="cpu")
    assert cfg == autotune.TileConfig(plan="tile", bt=8)
    # resolve() (the caller every kernel uses) keeps working too
    with pytest.warns(RuntimeWarning):
        assert autotune.resolve("engine_step", backend="cpu").plan == "tile"


def test_unexpected_cache_layout_warns(tmp_cache, monkeypatch):
    monkeypatch.setattr(autotune, "shipped_defaults", lambda: {})
    tmp_cache.write_text('{"entries": [1, 2, 3]}')      # list, not mapping
    with pytest.warns(RuntimeWarning, match="unexpected layout"):
        assert autotune.load_cache() == {}


def test_garbage_entry_values_fall_through(tmp_cache, monkeypatch):
    """Unparsable values INSIDE a parsable cache ("bt": "fast", bogus
    plans) skip the entry so the next precedence level wins, instead of
    poisoning resolution."""
    monkeypatch.setattr(autotune, "shipped_defaults", lambda: {
        "cpu|engine_step|*": {"plan": "rowwise", "bt": 4}})
    key = autotune.make_key("engine_step", 8, 24, 32, "float32", "cpu")
    autotune.save_cache({key: {"plan": "tile", "bt": "fast"},
                         "cpu|engine_step|*": {"plan": "diagonal", "bt": 2}})
    cfg = autotune.lookup("engine_step", 8, 24, 32, "float32", backend="cpu")
    assert cfg == autotune.TileConfig(plan="rowwise", bt=4)


def test_corrupt_cache_is_repairable_by_save(tmp_cache):
    tmp_cache.write_text("garbage")
    with pytest.warns(RuntimeWarning):
        assert autotune.load_cache() == {}
    autotune.record("engine_step", autotune.TileConfig("tile", 16),
                    backend="cpu")
    key = autotune.make_key("engine_step", 0, 0, 0, "float32", "cpu")
    assert autotune._from_entry(autotune.load_cache()[key]) \
        == autotune.TileConfig("tile", 16)
