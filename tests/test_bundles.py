"""Measure-kernel bundle registry tests (DESIGN.md §10): registration and
resolution semantics, fallback behavior, the no-meta-sniffing contract on
``engine._build``, and the serving acceptance pin — the continuous-batching
runtime runs unmodified (bit-identically vs one-shot search) on every
registered bundle with the fused kernel grad stage on."""
import inspect

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineOptions, SearchConfig, build_engine,
                        get_bundle, list_families, make_family_measure,
                        mlp_measure, register_bundle, resolve_stages)
from repro.core.bundles import _REGISTRY, MeasureKernelBundle
from repro.graph import build_l2_graph
from repro.serving import ContinuousRuntime, Request


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_builtin_families_registered():
    fams = list_families()
    assert "deepfm" in fams and "mlp" in fams
    for fam in ("deepfm", "mlp"):
        # both built-ins are full bundles: every stage slot kernel-backed
        assert all(get_bundle(fam).slots().values())


def test_register_bundle_duplicate_and_overwrite():
    b = MeasureKernelBundle(family="_test_family")
    try:
        register_bundle(b)
        with pytest.raises(ValueError):
            register_bundle(b)
        b2 = MeasureKernelBundle(family="_test_family",
                                 score=lambda meta, options: (lambda *a: a))
        register_bundle(b2, overwrite=True)
        assert get_bundle("_test_family") is b2
    finally:
        _REGISTRY.pop("_test_family", None)


def test_resolve_stages_fallback_and_routing():
    opts = EngineOptions()
    score_fn = lambda p, x, q: jnp.dot(x, q)
    # no meta -> every slot generic
    st = resolve_stages(score_fn, None, opts)
    assert st.measure.bundle_family == "generic"
    assert st.grad.bundle_family == "generic"
    assert st.measure_fused is None and st.grad_fused is None
    # unknown family -> generic fallback, not an error
    st = resolve_stages(score_fn, ("nope", 3), opts)
    assert st.measure.bundle_family == "generic"
    # the historical ('deepfm', fm_dim) tuple still resolves
    st = resolve_stages(score_fn, ("deepfm", 8), opts)
    assert st.measure.bundle_family == "deepfm"
    assert st.grad.bundle_family == "deepfm"
    # fused slots appear only under options.fused
    st = resolve_stages(score_fn, ("deepfm", 8),
                        EngineOptions(fused=True))
    assert st.measure_fused.bundle_family == "deepfm"
    assert st.grad_fused.bundle_family == "deepfm"
    # explicit vmap overrides bypass the bundle per stage kind
    st = resolve_stages(score_fn, ("deepfm", 8),
                        EngineOptions(measure_impl="vmap"))
    assert st.measure.bundle_family == "generic"
    assert st.grad.bundle_family == "deepfm"
    st = resolve_stages(score_fn, ("deepfm", 8),
                        EngineOptions(grad_impl="vmap", fused=True))
    assert st.grad.bundle_family == "generic"
    assert st.grad_fused is None          # no generic fused-grad kernel:
    #                                       the engine gathers + runs grad


def test_build_has_no_measure_conditionals():
    """The acceptance criterion, literally: engine._build contains no
    measure-name / meta-tuple sniffing — dispatch is registry-only."""
    from repro.core import engine as engine_mod
    src = inspect.getsource(engine_mod._build)
    assert "deepfm" not in src and "is_deepfm" not in src
    assert "meta[" not in src and "meta ==" not in src


def test_engine_stages_carry_bundle_family():
    m = mlp_measure(jax.random.PRNGKey(0), 12, 12, hidden=(16,))
    cfg = SearchConfig(k=5, ef=16)
    eng = build_engine(m, cfg, EngineOptions(fused=True))
    assert eng.measure.bundle_family == "mlp"
    assert eng.grad.bundle_family == "mlp"
    assert eng.measure_fused.bundle_family == "mlp"
    assert eng.grad_fused.bundle_family == "mlp"
    eng_v = build_engine(m, cfg, EngineOptions(measure_impl="vmap",
                                               grad_impl="vmap"))
    assert eng_v.measure.bundle_family == "generic"
    assert eng_v.grad.bundle_family == "generic"


# ---------------------------------------------------------------------------
# serving acceptance: continuous batching runs any registered bundle
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def serving_system():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(600, 16)).astype(np.float32)
    queries = rng.normal(size=(10, 16)).astype(np.float32)
    graph = build_l2_graph(base, m=8, k_construction=24)
    return dict(base=base, queries=queries, graph=graph)


@pytest.mark.parametrize("family", ["deepfm", "mlp"])
def test_continuous_runtime_runs_registered_bundles(serving_system, family):
    """Lane-recycling parity per bundle: a shuffled stream through the
    continuous runtime returns bit-identical ids/scores/counters to
    one-shot engine.search — with the bundle's kernel score AND fused grad
    stages resolved from the registry."""
    s = serving_system
    measure = make_family_measure(family, jax.random.PRNGKey(0), 16,
                                  hidden=(32,))
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg, EngineOptions(fused=True))
    assert eng.grad_fused is not None
    assert eng.measure.bundle_family == family
    Q = s["queries"].shape[0]
    ref = eng.search(measure.params, jnp.asarray(s["base"]),
                     jnp.asarray(s["graph"].neighbors),
                     jnp.asarray(s["queries"]),
                     jnp.full((Q,), s["graph"].entry, jnp.int32))
    rt = ContinuousRuntime(eng, measure.params, s["base"],
                           s["graph"].neighbors, n_lanes=4, query_dim=16,
                           entry=s["graph"].entry, steps_per_tick=3)
    order = np.random.default_rng(9).permutation(Q)
    comps = rt.run_stream(
        [Request(rid=int(i), query=s["queries"][i]) for i in order],
        realtime=False)
    assert len(comps) == Q
    by = {c.rid: c for c in comps}
    for i in range(Q):
        assert np.array_equal(by[i].ids, np.asarray(ref.ids)[i]), (family, i)
        assert np.array_equal(by[i].scores, np.asarray(ref.scores)[i])
        assert by[i].n_eval == int(ref.n_eval[i])
        assert by[i].n_grad == int(ref.n_grad[i])
    assert {c.lane for c in comps} == set(range(4))   # lanes recycled


def test_multi_measure_engines_share_runtime_code(serving_system):
    """The runtime is bundle-agnostic: the same ContinuousRuntime class
    (no subclassing, no family branches) served both families above; here
    we additionally pin that a deepfm engine and an mlp engine expose the
    identical lane-lifecycle surface the runtime drives."""
    cfg = SearchConfig(k=5, ef=16)
    engines = [build_engine(make_family_measure(f, jax.random.PRNGKey(0),
                                                16, hidden=(32,)), cfg)
               for f in ("deepfm", "mlp")]
    for eng in engines:
        for api in ("init_state", "reset_lanes", "idle_state", "step"):
            assert callable(getattr(eng, api))
