"""Paged corpus residency + streaming index mutation (DESIGN.md §11).

The acceptance pins: paged searches are BIT-IDENTICAL to whole-resident
searches at fp32 (single engine, sharded host merge, continuous runtime —
both measure bundles), the LRU pager stays inside its byte budget (modulo
the in-flight pinned working set), tombstoned rows never surface in
results while staying traversable, streaming inserts track a from-scratch
rebuild's recall within 1%, and delete→compact round-trips through io v3.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import (EngineOptions, SearchConfig, build_engine,
                        deepfm_measure, make_corpus_store, mlp_measure,
                        recall)
from repro.core.corpus import (PagedCorpusStore, ResidencyPolicy,
                               make_paged_store, pack_bitmap, unpack_bitmap)
from repro.core.sharded import (build_sharded_index, shard_stores,
                                sharded_search_stores)
from repro.graph import (MutationJournal, build_l2_graph, compact,
                         delete_rows, insert_rows, load_corpus_store,
                         load_index, load_journal, save_index, save_journal)
from repro.models import deepfm as deepfm_lib
from repro.serving import ContinuousRuntime, Request

PAGED = ResidencyPolicy("paged", page_rows=128, cache_bytes=1 << 20)


def _measure(family: str, dim: int):
    if family == "mlp":
        return mlp_measure(jax.random.PRNGKey(1), dim, dim, hidden=(32,))
    cfg_m = deepfm_lib.DeepFMConfig()
    assert cfg_m.vec_dim == dim
    params, _ = deepfm_lib.init_measure(jax.random.PRNGKey(0), cfg_m)
    return deepfm_measure(params, cfg_m)


@pytest.fixture(scope="module")
def system():
    dim = deepfm_lib.DeepFMConfig().vec_dim
    rng = np.random.default_rng(0)
    base = rng.normal(size=(600, dim)).astype(np.float32) * 0.5
    queries = rng.normal(size=(12, dim)).astype(np.float32) * 0.5
    graph = build_l2_graph(base, m=8, k_construction=24)
    return dict(base=base, queries=queries, graph=graph, dim=dim)


# ---------------------------------------------------------------------------
# paged == whole: the bit-identity pins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", ["deepfm", "mlp"])
@pytest.mark.parametrize("fused", [False, True])
def test_paged_search_bit_identical_single(system, family, fused):
    """fp32 paged search returns bit-identical ids AND scores (and
    counters) to the whole-resident run, fused and unfused, both measure
    bundles — residency is a policy, not a different search."""
    s = system
    measure = _measure(family, s["dim"])
    cfg = SearchConfig(k=10, ef=32, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg, EngineOptions(fused=fused))
    nbrs = jnp.asarray(s["graph"].neighbors)
    q = jnp.asarray(s["queries"])
    entries = jnp.full((q.shape[0],), s["graph"].entry, jnp.int32)
    whole = make_corpus_store(s["base"])
    paged = make_corpus_store(s["base"], residency=PAGED)
    r_w = eng.search(measure.params, whole, nbrs, q, entries)
    r_p = eng.search(measure.params, paged, nbrs, q, entries)
    np.testing.assert_array_equal(np.asarray(r_w.ids), np.asarray(r_p.ids))
    np.testing.assert_array_equal(np.asarray(r_w.scores),
                                  np.asarray(r_p.scores))
    np.testing.assert_array_equal(np.asarray(r_w.n_eval),
                                  np.asarray(r_p.n_eval))


@pytest.mark.parametrize("dtype", ["float32", "bfloat16", "int8"])
def test_paged_take_matches_whole(system, dtype):
    """The pager's host-side dequant twins reproduce the device gather
    bit-for-bit in every residency dtype."""
    s = system
    rng = np.random.default_rng(1)
    ids = jnp.asarray(rng.integers(0, 600, size=(5, 7)).astype(np.int32))
    whole = make_corpus_store(s["base"], dtype)
    paged = make_corpus_store(s["base"], dtype, residency=PAGED)
    np.testing.assert_array_equal(np.asarray(whole.take(ids)),
                                  np.asarray(paged.take(ids)))


@pytest.mark.parametrize("family", ["deepfm", "mlp"])
def test_paged_search_bit_identical_sharded(system, family):
    """sharded_search_stores over paged per-shard stores == whole stores."""
    s = system
    measure = _measure(family, s["dim"])
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    idx = build_sharded_index(s["base"], n_shards=2, m=8, k_construction=24)
    r_w = sharded_search_stores(measure, shard_stores(idx), idx,
                                s["queries"], cfg)
    r_p = sharded_search_stores(measure,
                                shard_stores(idx, residency=PAGED), idx,
                                s["queries"], cfg)
    np.testing.assert_array_equal(r_w.ids, r_p.ids)
    np.testing.assert_array_equal(r_w.scores, r_p.scores)
    np.testing.assert_array_equal(r_w.n_eval, r_p.n_eval)
    np.testing.assert_array_equal(r_w.n_iters, r_p.n_iters)


def test_paged_search_bit_identical_continuous(system):
    """The continuous-batching runtime accepts a paged store and completes
    the same stream bit-identically to the whole-resident runtime."""
    s = system
    measure = _measure("mlp", s["dim"])
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg,
                       EngineOptions(rank_impl="ref", measure_impl="vmap"))
    g = s["graph"]
    Q = s["queries"].shape[0]
    stream = [Request(rid=i, query=s["queries"][i]) for i in range(Q)]
    comps = {}
    for name, corpus in (("whole", s["base"]),
                         ("paged", make_corpus_store(s["base"],
                                                     residency=PAGED))):
        rt = ContinuousRuntime(eng, measure.params, corpus, g.neighbors,
                               n_lanes=4, query_dim=s["dim"], entry=g.entry,
                               steps_per_tick=3)
        comps[name] = {c.rid: c for c in rt.run_stream(stream,
                                                       realtime=False)}
    for i in range(Q):
        w, p = comps["whole"][i], comps["paged"][i]
        np.testing.assert_array_equal(w.ids, p.ids)
        np.testing.assert_array_equal(w.scores, p.scores)
        assert w.n_eval == p.n_eval and w.n_iters == p.n_iters


def test_paged_rejects_pallas_fused(system):
    """The Pallas index-fused kernels read device-resident payloads; a
    paged (host-pager) store cannot feed them — fail loudly at init."""
    s = system
    measure = _measure("mlp", s["dim"])
    eng = build_engine(measure, SearchConfig(k=5, ef=16),
                       EngineOptions(fused=True, rank_impl="pallas"))
    paged = make_corpus_store(s["base"], residency=PAGED)
    q = jnp.asarray(s["queries"][:2])
    with pytest.raises(ValueError, match="paged"):
        eng.init_state(measure.params, paged, jnp.asarray(
            s["graph"].neighbors), q, jnp.zeros((2,), jnp.int32))


# ---------------------------------------------------------------------------
# the pager itself
# ---------------------------------------------------------------------------

def test_lru_evicts_cold_pages_under_budget(system):
    """Disjoint sequential gathers over a corpus larger than the budget:
    cold pages are evicted, the footprint stays at budget + the in-flight
    pinned working set, and every gather is still exact."""
    base = system["base"]
    page_rows, dim = 64, base.shape[1]
    page_bytes = page_rows * dim * 4
    policy = ResidencyPolicy("paged", page_rows=page_rows,
                             cache_bytes=3 * page_bytes)
    store = make_paged_store(base, "float32", policy)
    for start in range(0, 512, page_rows):      # 8 disjoint pages
        ids = np.arange(start, start + page_rows)
        np.testing.assert_array_equal(store.cache.gather(ids), base[ids])
    st = store.stats_snapshot()
    assert st.evictions > 0
    assert st.resident_bytes <= policy.cache_bytes
    assert st.peak_resident_bytes <= policy.cache_bytes + page_bytes
    # a re-gather of the hottest (most recent) page is a pure hit
    hits0 = st.hits
    store.cache.gather(np.arange(512 - page_rows, 512))
    assert store.stats_snapshot().hits > hits0


def test_pack_unpack_bitmap_round_trip():
    rng = np.random.default_rng(2)
    flags = rng.random(197) < 0.3
    assert np.array_equal(unpack_bitmap(pack_bitmap(flags), 197), flags)


def test_paged_store_is_jit_compatible(system):
    """A PagedCorpusStore flows through jit as a pytree (the page cache is
    static aux data; the callback gathers on host)."""
    paged = make_corpus_store(system["base"], residency=PAGED)
    assert isinstance(paged, PagedCorpusStore)

    @jax.jit
    def take2(store, ids):
        return store.take(ids) * 2.0
    ids = jnp.asarray([1, 5, 599])
    np.testing.assert_allclose(np.asarray(take2(paged, ids)),
                               system["base"][np.asarray(ids)] * 2.0,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# streaming mutation
# ---------------------------------------------------------------------------

def test_deleted_rows_never_surface(system):
    """Tombstoned rows are scored -inf at pool insert: they stay
    traversable (graph connectivity) but cannot appear in results."""
    s = system
    measure = _measure("mlp", s["dim"])
    cfg = SearchConfig(k=10, ef=32, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg)
    # delete the whole-resident run's top answers — the strongest attractors
    whole = make_corpus_store(s["base"])
    nbrs = jnp.asarray(s["graph"].neighbors)
    q = jnp.asarray(s["queries"])
    entries = jnp.full((q.shape[0],), s["graph"].entry, jnp.int32)
    r0 = eng.search(measure.params, whole, nbrs, q, entries)
    victims = np.unique(np.asarray(r0.ids)[:, :3].ravel())
    victims = victims[victims >= 0]
    g2 = delete_rows(s["graph"], victims)
    for residency in (None, PAGED):
        store = make_corpus_store(s["base"], residency=residency,
                                  tombstones=g2.tombstones)
        entries2 = jnp.full((q.shape[0],), g2.entry, jnp.int32)
        r = eng.search(measure.params, store, nbrs, q, entries2)
        ids = np.asarray(r.ids)
        assert not np.isin(ids[ids >= 0], victims).any()
        assert (ids >= 0).any()     # searches still return live answers


def test_insert_recall_within_1pct_of_rebuild(system):
    """Streaming insert of 100 rows into a 500-row index: engine recall on
    the grown index stays within 1% of a from-scratch rebuild over the
    same 600 rows (the ISSUE smoke shape)."""
    s = system
    base, dim = s["base"], s["dim"]
    old, new = base[:500], base[500:600]
    g_inc = insert_rows(build_l2_graph(old, m=8, k_construction=24), new)
    g_reb = build_l2_graph(base[:600], m=8, k_construction=24)
    assert g_inc.n == 600 and g_inc.base.shape == g_reb.base.shape

    measure = _measure("mlp", dim)
    cfg = SearchConfig(k=10, ef=32, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg)
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(64, dim)).astype(np.float32) * 0.5)
    from repro.core import brute_force_topk
    true_ids, _ = brute_force_topk(measure, jnp.asarray(g_reb.base), q, 10)
    recalls = {}
    for name, g in (("inc", g_inc), ("reb", g_reb)):
        res = eng.search(measure.params, jnp.asarray(g.base),
                         jnp.asarray(g.neighbors), q,
                         jnp.full((64,), g.entry, jnp.int32))
        recalls[name] = float(recall(res.ids, true_ids))
    assert recalls["inc"] >= recalls["reb"] - 0.01, recalls


def test_insert_neighbors_stay_valid(system):
    g = build_l2_graph(system["base"][:300], m=8, k_construction=24)
    rng = np.random.default_rng(7)
    new = rng.normal(size=(40, system["dim"])).astype(np.float32) * 0.5
    g2 = insert_rows(g, new)
    assert g2.n == 340
    nbrs = g2.neighbors
    assert nbrs.shape[0] == 340 and nbrs.max() < 340
    # new nodes are reachable: somebody points at them
    assert np.isin(np.arange(300, 340), nbrs).any()
    # no self-loops anywhere
    rows = np.arange(340)[:, None]
    assert not (nbrs == rows).any()


def test_delete_reassigns_dead_entry(system):
    g = build_l2_graph(system["base"][:200], m=8, k_construction=24)
    g2 = delete_rows(g, [g.entry])
    assert g2.entry != g.entry and not g2.tombstones[g2.entry]
    with pytest.raises(ValueError):
        delete_rows(g2, np.arange(200))    # cannot delete every row


def test_compact_remaps_and_drops_tombstones(system):
    base = system["base"][:250]
    g = build_l2_graph(base, m=8, k_construction=24)
    dead = np.asarray([3, 17, 101, 249])
    g2 = compact(delete_rows(g, dead))
    assert g2.n == 246 and g2.tombstones is None
    # survivors keep their vectors, in order
    keep = np.setdiff1d(np.arange(250), dead)
    np.testing.assert_array_equal(g2.base, base[keep])
    assert g2.neighbors.max() < 246
    # no survivor's neighbor list references a dropped row's old id: remap
    # happened (valid ids point at the same VECTOR as before)
    old_of = keep
    for i in [0, 100, 245]:
        for j in g2.neighbors[i]:
            if j >= 0:
                np.testing.assert_array_equal(g2.base[j], base[old_of[j]])


def test_mutation_journal_round_trip(tmp_path):
    j = MutationJournal(n_base=500)
    j.record("insert", n=100)
    j.record("delete", ids=[1, 2, 3])
    save_journal(str(tmp_path / "idx"), j)
    j2 = load_journal(str(tmp_path / "idx"))
    assert j2.n_base == 500 and j2.n_inserted == 100 and j2.n_deleted == 3
    assert j2.ops == j.ops
    assert load_journal(str(tmp_path / "nope")) is None


def test_delete_compact_io_v3_round_trip(system, tmp_path):
    """delete → save (tombstones persisted) → load → compact → save → load:
    every leg round-trips through the v3 on-disk layout."""
    base = system["base"][:300]
    g = delete_rows(build_l2_graph(base, m=8, k_construction=24),
                    [5, 50, 150])
    save_index(str(tmp_path / "a"), g, page_rows=64)
    g2 = load_index(str(tmp_path / "a"))
    np.testing.assert_array_equal(g2.tombstones, g.tombstones)
    assert g2.n_alive == 297
    # paged load honors the persisted tombstones too
    st = load_corpus_store(str(tmp_path / "a"),
                           residency=ResidencyPolicy("paged"))
    assert st.tombstones is not None
    gc = compact(g2)
    save_index(str(tmp_path / "b"), gc, page_rows=64)
    g3 = load_index(str(tmp_path / "b"))
    assert g3.n == 297 and g3.tombstones is None
    np.testing.assert_array_equal(g3.base, gc.base)
    np.testing.assert_array_equal(g3.neighbors, gc.neighbors)


# ---------------------------------------------------------------------------
# index-version epochs in the continuous runtime
# ---------------------------------------------------------------------------

def test_install_index_epochs(system):
    """In-flight lanes finish on the epoch they were admitted under; the
    staged index swaps once they drain; later admissions search the new
    epoch (and can return the inserted rows)."""
    s = system
    measure = _measure("mlp", s["dim"])
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    eng = build_engine(measure, cfg,
                       EngineOptions(rank_impl="ref", measure_impl="vmap"))
    g = s["graph"]
    rt = ContinuousRuntime(eng, measure.params, s["base"], g.neighbors,
                           n_lanes=2, query_dim=s["dim"], entry=g.entry,
                           steps_per_tick=1)
    rt.submit(s["queries"][0], rid=0)
    rt.step_once()                       # rid 0 admitted under epoch 0
    assert rt.in_flight == 1

    rng = np.random.default_rng(9)
    new = rng.normal(size=(30, s["dim"])).astype(np.float32) * 0.5
    g2 = insert_rows(g, new)
    staged = rt.install_index(np.asarray(g2.base), g2.neighbors, g2.entry)
    assert staged == 1 and rt.epoch == 0
    rt.submit(s["queries"][1], rid=1)    # queued; holds for the swap
    comps = []
    for _ in range(600):
        comps += rt.step_once()
        if len(comps) == 2:
            break
    by = {c.rid: c for c in comps}
    assert by[0].epoch == 0 and by[1].epoch == 1
    assert rt.epoch == 1 and rt.store.n == g2.n
    # the post-swap result is exactly the one-shot search on the new index
    ref = eng.search(measure.params, jnp.asarray(g2.base),
                     jnp.asarray(g2.neighbors),
                     jnp.asarray(s["queries"][1:2]),
                     jnp.full((1,), g2.entry, jnp.int32))
    np.testing.assert_array_equal(by[1].ids, np.asarray(ref.ids)[0])
    np.testing.assert_array_equal(by[1].scores, np.asarray(ref.scores)[0])
