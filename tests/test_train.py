"""Training substrate: optimizer math, microbatching, checkpoint/restart,
gradient compression, straggler/elastic policies."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.ft import StragglerMonitor, remesh_plan
from repro.ft.checkpoint import (latest_step, restore_checkpoint,
                                 save_checkpoint)
from repro.train import compress
from repro.train.optimizer import (OptimizerConfig, adamw_init, adamw_update,
                                   clip_by_global_norm, cosine_schedule)
from repro.train.trainer import Trainer, TrainerConfig, make_train_step


def test_adamw_matches_reference():
    """One AdamW step vs a hand-written numpy reference."""
    cfg = OptimizerConfig(lr=0.1, betas=(0.9, 0.999), eps=1e-8,
                          weight_decay=0.0, grad_clip=0.0, warmup_steps=0,
                          total_steps=1_000_000)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    st_ = adamw_init(p, cfg)
    p2, st2, _ = adamw_update(p, g, st_, cfg)
    # reference
    gn = np.asarray(g["w"])
    m = 0.1 * gn
    v = 0.001 * gn * gn
    lr = cosine_schedule(jnp.int32(1), cfg)
    upd = (m / (1 - 0.9)) / (np.sqrt(v / (1 - 0.999)) + 1e-8)
    ref = np.asarray(p["w"]) - np.asarray(lr) * upd
    np.testing.assert_allclose(np.asarray(p2["w"]), ref, rtol=1e-5)


def test_grad_clip():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(total - 1.0) < 1e-4
    assert abs(float(norm) - np.sqrt(1000.0)) < 1e-2


def test_schedule_shape():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(cosine_schedule(jnp.int32(s), cfg)) for s in (0, 5, 10, 55, 100)]
    assert lrs[0] == 0.0 and abs(lrs[1] - 0.5) < 1e-5 and abs(lrs[2] - 1.0) < 1e-5
    assert lrs[3] < 1.0 and lrs[4] < 0.01


def _quad_loss(params, batch):
    return jnp.sum((params["w"] - batch["target"]) ** 2)


def test_trainer_converges_and_restarts():
    params = {"w": jnp.zeros((4,))}
    target = jnp.asarray([1.0, 2.0, -1.0, 0.5])
    batch_fn = lambda step: {"target": target}
    with tempfile.TemporaryDirectory() as d:
        tr = Trainer(_quad_loss, params, OptimizerConfig(lr=0.1, total_steps=200),
                     TrainerConfig(total_steps=60, ckpt_every=20, ckpt_dir=d))
        tr.run(batch_fn)
        assert float(jnp.abs(tr.params["w"] - target).max()) < 0.2
        assert latest_step(d) == 60
        # restart continues, state intact
        tr2 = Trainer(_quad_loss, params, OptimizerConfig(lr=0.1, total_steps=200),
                      TrainerConfig(total_steps=80, ckpt_every=20, ckpt_dir=d))
        assert tr2.maybe_restore() == 60
        np.testing.assert_allclose(np.asarray(tr2.params["w"]),
                                   np.asarray(tr.params["w"]))
        tr2.run(batch_fn)
        assert int(tr2.opt_state.step) == 80


def test_checkpoint_atomicity_and_shape_check():
    with tempfile.TemporaryDirectory() as d:
        tree = {"a": jnp.ones((3, 4)), "b": {"c": jnp.zeros((2,))}}
        save_checkpoint(d, 5, tree)
        back = restore_checkpoint(d, tree)
        np.testing.assert_allclose(np.asarray(back["a"]), np.ones((3, 4)))
        # wrong-shape template must fail loudly
        with pytest.raises(Exception):
            restore_checkpoint(d, {"a": jnp.ones((9, 9)),
                                   "b": {"c": jnp.zeros((2,))}})


def test_microbatch_equivalence():
    params = {"w": jnp.arange(8.0)}
    batch = {"target": jnp.ones((8, 8))}

    def loss(p, b):
        return jnp.mean((p["w"][None, :] - b["target"]) ** 2)

    cfg = OptimizerConfig(lr=0.05, grad_clip=0.0)
    s1 = make_train_step(loss, cfg, 1, donate=False)
    s4 = make_train_step(loss, cfg, 4, donate=False)
    p1, _, m1 = s1(params, adamw_init(params, cfg), batch)
    p4, _, m4 = s4(params, adamw_init(params, cfg), batch)
    np.testing.assert_allclose(np.asarray(p1["w"]), np.asarray(p4["w"]),
                               rtol=1e-6)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-6


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 1000))
def test_int8_compression_bounded_error(seed):
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    q, s = compress.quantize_int8(g)
    back = compress.dequantize_int8(q, s)
    max_err = float(jnp.abs(g - back).max())
    assert max_err <= float(s) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """Sum of decompressed grads + final residual == sum of true grads."""
    rng = np.random.default_rng(0)
    grads = [
        {"w": jnp.asarray(rng.normal(size=(32,)).astype(np.float32))}
        for _ in range(16)
    ]
    err = compress.init_error_state(grads[0])
    total_sent = jnp.zeros((32,))
    for g in grads:
        comp, err = compress.compress_int8_ef(g, err)
        total_sent = total_sent + compress.decompress_int8(comp)["w"]
    total_true = sum(np.asarray(g["w"]) for g in grads)
    np.testing.assert_allclose(np.asarray(total_sent + err["w"]), total_true,
                               rtol=1e-4, atol=1e-4)


def test_topk_compression_roundtrip():
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(size=(100,)).astype(np.float32))}
    err = compress.init_error_state(g)
    comp, err2 = compress.topk_compress_ef(g, err, frac=0.1)
    vals, idx = comp["w"]
    assert vals.shape[0] == 10
    dense = compress.topk_densify(vals, idx, (100,))
    # kept entries match, rest in residual
    np.testing.assert_allclose(np.asarray(dense + err2["w"]),
                               np.asarray(g["w"]), rtol=1e-5, atol=1e-5)


def test_straggler_ladder():
    mon = StragglerMonitor(n_hosts=8, threshold=1.5, patience=2)
    normal = {i: 1.0 for i in range(8)}
    assert mon.record_step(normal).kind == "none"
    slow = {**normal, 3: 5.0}
    assert mon.record_step(slow).kind == "none"       # patience not reached
    act = mon.record_step(slow)
    assert act.kind == "rebalance" and act.hosts == [3]
    for _ in range(4):
        act = mon.record_step(slow)
    assert act.kind in ("swap", "reshard")
    assert 3 not in mon.healthy_hosts()


def test_elastic_remesh():
    plan = remesh_plan(384, (16, 16))
    assert plan is not None and plan.new_shape == (24, 16)
    assert "preserved" in plan.note
    plan2 = remesh_plan(24, (16, 16))
    assert plan2 is not None and plan2.new_shape[0] * plan2.new_shape[1] == 24
    assert remesh_plan(7, (16, 16), model_divisors=(16, 8, 4, 2)) is None


def test_elastic_restore_roundtrip():
    """Checkpoint written under one 'mesh' restores under another shape of
    the same arrays (npz stores full arrays; shardings reapplied)."""
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.arange(64.0).reshape(8, 8)}
        save_checkpoint(d, 1, tree)
        back = restore_checkpoint(d, tree)
        np.testing.assert_allclose(np.asarray(back["w"]),
                                   np.arange(64.0).reshape(8, 8))
