"""Crash-safe mutation recovery (DESIGN.md §12): torn-journal tolerance,
the append-fsync commit point, the ``journal_applied`` watermark, and the
headline contract — a kill injected at ANY durability stage of ANY op
recovers to the bit-exact uninterrupted index (at most the un-journaled
op is lost, and re-applying it restores equality)."""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.graph import (DurableIndex, MutationJournal, append_journal,
                         apply_op, build_l2_graph, insert_rows, load_index,
                         load_journal, save_index, save_journal)
from repro.serving import FaultEvent, FaultPlan, InjectedKill

RNG = np.random.default_rng(11)
BASE = RNG.normal(size=(80, 8)).astype(np.float32)
NEW_ROWS = RNG.normal(size=(6, 8)).astype(np.float32)
DEL_IDS = [3, 17, 40, 81]          # 81: one of the freshly inserted rows

# the canonical mutation sequence the kill matrix sweeps (op index = the
# per-stage invocation index of DurableIndex._commit's kill hooks)
OPS = [("insert", lambda d: d.insert(NEW_ROWS, k_candidates=16)),
       ("delete", lambda d: d.delete(DEL_IDS)),
       ("compact", lambda d: d.compact())]


@pytest.fixture(scope="module")
def ref(tmp_path_factory):
    """The uninterrupted twin: same lineage with no kills."""
    graph = build_l2_graph(BASE, m=4, k_construction=12)
    d = DurableIndex.create(str(tmp_path_factory.mktemp("ref")), graph)
    for _, fn in OPS:
        fn(d)
    return {"graph": graph, "final": d.index}


def _assert_same_index(a, b):
    np.testing.assert_array_equal(np.asarray(a.base), np.asarray(b.base))
    np.testing.assert_array_equal(np.asarray(a.neighbors),
                                  np.asarray(b.neighbors))
    assert int(a.entry) == int(b.entry)
    ta = None if a.tombstones is None else np.asarray(a.tombstones, bool)
    tb = None if b.tombstones is None else np.asarray(b.tombstones, bool)
    if ta is None or tb is None:       # None <=> nothing tombstoned
        assert ta is None or not ta.any()
        assert tb is None or not tb.any()
    else:
        np.testing.assert_array_equal(ta, tb)


# ---------------------------------------------------------------------------
# journal file damage tolerance
# ---------------------------------------------------------------------------

def _jl(*records) -> str:
    return "\n".join(json.dumps(r) for r in records) + "\n"


def test_journal_jsonl_round_trip(tmp_path):
    j = MutationJournal(n_base=80)
    j.record("insert", n=2, k_candidates=16, rows=NEW_ROWS[:2].tolist())
    j.record("delete", ids=[1, 2])
    j.record("compact", n_dropped=2)
    save_journal(str(tmp_path), j)
    j2 = load_journal(str(tmp_path))
    assert j2.n_base == 80 and j2.ops == j.ops
    assert j2.n_inserted == 2 and j2.n_deleted == 2


def test_append_journal_is_incremental_and_needs_header(tmp_path):
    with pytest.raises(FileNotFoundError):
        append_journal(str(tmp_path), {"op": "compact", "n_dropped": 0})
    save_journal(str(tmp_path), MutationJournal(n_base=80))
    append_journal(str(tmp_path), {"op": "delete", "ids": [5]})
    append_journal(str(tmp_path), {"op": "compact", "n_dropped": 1})
    j = load_journal(str(tmp_path))
    assert j.ops == [{"op": "delete", "ids": [5]},
                     {"op": "compact", "n_dropped": 1}]


def test_legacy_whole_file_journal_still_loads(tmp_path):
    legacy = {"n_base": 80, "ops": [{"op": "delete", "ids": [2]}]}
    (tmp_path / "journal.json").write_text(json.dumps(legacy))
    j = load_journal(str(tmp_path))
    assert j.n_base == 80 and j.ops == legacy["ops"]


def test_torn_final_line_truncates_with_warning(tmp_path):
    good = {"op": "delete", "ids": [1]}
    (tmp_path / "journal.json").write_text(
        _jl({"n_base": 80}, good) + '{"op": "ins')      # kill mid-append
    with pytest.warns(RuntimeWarning, match="torn/garbage"):
        j = load_journal(str(tmp_path))
    assert j.n_base == 80 and j.ops == [good]


def test_garbage_ends_the_trustworthy_prefix(tmp_path):
    good = {"op": "delete", "ids": [1]}
    after = {"op": "compact", "n_dropped": 0}
    (tmp_path / "journal.json").write_text(
        _jl({"n_base": 80}, good) + "\x00\x7fgarbage\n" + _jl(after))
    with pytest.warns(RuntimeWarning, match="2 torn/garbage"):
        j = load_journal(str(tmp_path))
    assert j.ops == [good]             # everything past the tear is dropped


def test_empty_or_headerless_journal_is_unmutated(tmp_path):
    (tmp_path / "journal.json").write_text("")
    with pytest.warns(RuntimeWarning, match="no readable header"):
        assert load_journal(str(tmp_path)) is None
    (tmp_path / "journal.json").write_text("complete nonsense\n")
    with pytest.warns(RuntimeWarning):
        assert load_journal(str(tmp_path)) is None
    assert load_journal(str(tmp_path / "nowhere")) is None


# ---------------------------------------------------------------------------
# op replay
# ---------------------------------------------------------------------------

def test_apply_op_rejects_unreplayable_records(ref):
    with pytest.raises(ValueError, match="cannot be replayed"):
        apply_op(ref["graph"], {"op": "insert", "n": 3})   # payload-less
    with pytest.raises(ValueError, match="unknown journal op"):
        apply_op(ref["graph"], {"op": "transmogrify"})


def test_recover_legacy_dir_does_not_double_replay(tmp_path, ref):
    # legacy flow: save AFTER mutating, no journal_applied watermark =>
    # the journaled ops are already absorbed by the arrays — recovery must
    # default to all-applied, not replay them a second time
    from repro.graph.mutate import recover_index

    j = MutationJournal(n_base=80)
    g2 = insert_rows(ref["graph"], NEW_ROWS, k_candidates=16, journal=j)
    save_index(str(tmp_path), g2)
    save_journal(str(tmp_path), j)
    rec, j2 = recover_index(str(tmp_path))
    assert rec.n == g2.n               # a replay would have grown it again
    _assert_same_index(rec, g2)
    assert j2.ops == j.ops


# ---------------------------------------------------------------------------
# the kill matrix: die at every stage of every op, recover bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stage", ["pre-journal", "post-journal"])
@pytest.mark.parametrize("op_i", [0, 1, 2])
def test_kill_mid_mutation_recovers_exactly(tmp_path, ref, stage, op_i):
    plan = FaultPlan([FaultEvent("kill", site=f"mutate/{stage}",
                                 start=op_i)])
    d = DurableIndex.create(str(tmp_path), ref["graph"],
                            kill_hook=plan.kill_hook())
    with pytest.raises(InjectedKill):
        for _, fn in OPS:
            fn(d)
    d2 = DurableIndex.open(str(tmp_path))
    committed = len(d2.journal.ops)
    # pre-journal death loses the op entirely; post-journal death loses
    # nothing (the fsynced line replays on recovery)
    assert committed == op_i + (1 if stage == "post-journal" else 0)
    for _, fn in OPS[committed:]:      # redo what the crash lost
        fn(d2)
    _assert_same_index(d2.index, ref["final"])


@pytest.mark.parametrize("stage", ["pre-save", "post-save"])
def test_kill_during_checkpoint_keeps_a_durable_baseline(tmp_path, ref,
                                                         stage):
    # create() runs checkpoint #0, so start=1 targets the explicit
    # checkpoint after the mutations
    plan = FaultPlan([FaultEvent("kill", site=f"mutate/{stage}", start=1)])
    d = DurableIndex.create(str(tmp_path), ref["graph"],
                            kill_hook=plan.kill_hook())
    for _, fn in OPS:
        fn(d)
    with pytest.raises(InjectedKill):
        d.checkpoint()
    d2 = DurableIndex.open(str(tmp_path))
    _assert_same_index(d2.index, ref["final"])
    if stage == "pre-save":
        # baseline is still checkpoint #0: the whole journal replayed
        assert len(d2.journal.ops) == len(OPS)
        assert load_index(str(tmp_path)).n == ref["graph"].n
    else:
        # save landed before the kill: arrays absorb every op, watermark
        # says so, and the on-disk index already IS the final state
        _assert_same_index(load_index(str(tmp_path)), ref["final"])


def test_checkpoint_then_reopen_round_trips(tmp_path, ref):
    d = DurableIndex.create(str(tmp_path), ref["graph"])
    for _, fn in OPS:
        fn(d)
    d.checkpoint()
    d2 = DurableIndex.open(str(tmp_path))
    assert len(d2.journal.ops) == len(OPS)
    _assert_same_index(d2.index, ref["final"])
    _assert_same_index(load_index(str(tmp_path)), ref["final"])
