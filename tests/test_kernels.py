"""Per-kernel shape/dtype sweeps: Pallas (interpret=True on CPU) vs the
pure-jnp ref.py oracle, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import layers as L


# ---------------------------------------------------------------------------
# deepfm_score
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,d,hidden", [(64, 40, 64), (256, 40, 64),
                                        (555, 48, 32), (1, 24, 16)])
def test_deepfm_score_sweep(n, d, hidden):
    from repro.kernels.deepfm_score import deepfm_score
    from repro.kernels.deepfm_score.ref import deepfm_score_ref
    k = jax.random.PRNGKey(n)
    fm, deep = 8, d - 8
    mlp, _ = L.init_mlp(k, [2 * deep, hidden, hidden, 1], jnp.float32)
    cand = jax.random.normal(k, (n, d))
    q = jax.random.normal(jax.random.PRNGKey(1), (d,))
    out = deepfm_score(cand, q, mlp, fm_dim=fm)
    ref = deepfm_score_ref(cand, jnp.broadcast_to(q, cand.shape),
                           mlp["w"][0], mlp["b"][0], mlp["w"][1], mlp["b"][1],
                           mlp["w"][2], mlp["b"][2], fm_dim=fm)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# neighbor_rank
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,b,d,rank_by,alpha", [
    (4, 16, 40, "angle", 1.01), (16, 32, 40, "projection", 2.0),
    (7, 48, 64, "angle", 1.5), (1, 8, 16, "projection", 1.0),
])
def test_neighbor_rank_sweep(q, b, d, rank_by, alpha):
    from repro.kernels.neighbor_rank import neighbor_rank
    from repro.kernels.neighbor_rank.ref import neighbor_rank_ref
    k = jax.random.PRNGKey(q * b)
    x = jax.random.normal(k, (q, d))
    g = jax.random.normal(jax.random.PRNGKey(1), (q, d))
    nv = jax.random.normal(jax.random.PRNGKey(2), (q, b, d))
    valid = jax.random.bernoulli(jax.random.PRNGKey(3), 0.75, (q, b))
    valid = valid.at[:, 0].set(True)   # at least one valid per row
    key_k, mask_k = neighbor_rank(x, g, nv, valid, alpha=alpha, rank_by=rank_by)
    key_r, mask_r = neighbor_rank_ref(x, g, nv, valid, alpha=alpha, rank_by=rank_by)
    fin = np.isfinite(np.asarray(key_r))
    np.testing.assert_allclose(np.asarray(key_k)[fin], np.asarray(key_r)[fin],
                               rtol=1e-5, atol=1e-5)
    assert (np.asarray(mask_k) == np.asarray(mask_r)).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 24), st.integers(4, 40), st.floats(1.0, 3.0))
def test_neighbor_rank_properties(b, d, alpha):
    """Properties of Eq.3: mask subset of valid; the best-angle neighbor is
    always selected; alpha=inf-ish admits all valid."""
    from repro.kernels.neighbor_rank.ref import neighbor_rank_ref
    k = jax.random.PRNGKey(b * d)
    x = jax.random.normal(k, (2, d))
    g = jax.random.normal(jax.random.PRNGKey(1), (2, d)) + 0.1
    nv = jax.random.normal(jax.random.PRNGKey(2), (2, b, d))
    valid = jnp.ones((2, b), bool)
    key, mask = neighbor_rank_ref(x, g, nv, valid, alpha=alpha)
    key_np, mask_np = np.asarray(key), np.asarray(mask)
    assert mask_np.any(axis=1).all(), "best neighbor must survive pruning"
    best = key_np.argmin(axis=1)
    assert mask_np[np.arange(2), best].all()
    # monotone in alpha
    _, mask_hi = neighbor_rank_ref(x, g, nv, valid, alpha=alpha + 1.0)
    assert (np.asarray(mask_hi) | ~mask_np).all()


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r,d,b,l,dtype", [
    (100, 16, 8, 4, jnp.float32), (500, 64, 33, 8, jnp.float32),
    (64, 128, 16, 2, jnp.bfloat16),
])
def test_embedding_bag_sweep(r, d, b, l, dtype):
    from repro.kernels.embedding_bag import embedding_bag
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    k = jax.random.PRNGKey(r)
    table = jax.random.normal(k, (r, d), dtype)
    idx = jax.random.randint(jax.random.PRNGKey(1), (b, l), -1, r)
    w = jax.random.uniform(jax.random.PRNGKey(2), (b, l), dtype)
    out = embedding_bag(table, idx, w)
    ref = embedding_bag_ref(table, idx, w)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 50), st.integers(1, 6), st.integers(1, 16))
def test_embedding_bag_matches_loop(rows, l, d):
    """Hypothesis: bag == explicit python loop over indices."""
    from repro.kernels.embedding_bag.ref import embedding_bag_ref
    rng = np.random.default_rng(rows * l * d)
    table = rng.normal(size=(rows, d)).astype(np.float32)
    idx = rng.integers(-1, rows, size=(3, l)).astype(np.int32)
    ref = np.zeros((3, d), np.float32)
    for i in range(3):
        for j in range(l):
            if idx[i, j] >= 0:
                ref[i] += table[idx[i, j]]
    out = embedding_bag_ref(jnp.asarray(table), jnp.asarray(idx))
    np.testing.assert_allclose(np.asarray(out), ref, rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# decode_attn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,kv,hd,t,ln,bt", [
    (2, 8, 2, 32, 128, 100, 64), (1, 4, 4, 64, 300, 300, 128),
    (3, 8, 4, 16, 1024, 77, 256), (2, 16, 8, 64, 512, 512, 512),
])
def test_decode_attn_sweep(b, h, kv, hd, t, ln, bt):
    from repro.kernels.decode_attn import decode_attention
    from repro.kernels.decode_attn.ref import decode_attention_ref
    k = jax.random.PRNGKey(b * t)
    q = jax.random.normal(k, (b, h, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (b, t, kv, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (b, t, kv, hd))
    out = decode_attention(q, kc, vc, ln, block_t=bt)
    ref = decode_attention_ref(q, kc, vc, jnp.int32(ln))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_decode_attn_matches_gqa_layer():
    """Kernel == the model's grouped attention on a cache prefix."""
    from repro.kernels.decode_attn import decode_attention
    B, H, KV, hd, T, ln = 2, 8, 4, 32, 256, 199
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, 1, H, hd))
    kc = jax.random.normal(jax.random.PRNGKey(1), (B, T, KV, hd))
    vc = jax.random.normal(jax.random.PRNGKey(2), (B, T, KV, hd))
    mask = jnp.arange(T)[None, :] < ln
    ref = L.gqa_attention(q, kc, vc, mask=mask)[:, 0]
    out = decode_attention(q[:, 0], kc, vc, ln, block_t=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# flash_attn (causal forward)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,h,hd,bq,bk", [
    (2, 128, 4, 32, 32, 32), (1, 100, 2, 16, 32, 32),
    (2, 256, 2, 64, 64, 128), (1, 64, 8, 8, 64, 16),
])
def test_flash_attention_sweep(b, s, h, hd, bq, bk):
    from repro.kernels.flash_attn import flash_attention
    from repro.kernels.flash_attn.ref import flash_attention_ref
    k = jax.random.PRNGKey(s)
    q = jax.random.normal(k, (b, s, h, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, hd))
    out = flash_attention(q, kk, v, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q, kk, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_matches_model_layer():
    from repro.kernels.flash_attn import flash_attention
    B, S, H, hd = 2, 64, 4, 16
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    ref = L.mha_attention(q, kk, v, mask=L.causal_mask(S))
    out = flash_attention(q, kk, v, block_q=32, block_k=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref, np.float32),
                               rtol=2e-4, atol=2e-4)


def test_chunked_causal_mha_matches_full():
    B, S, H, hd = 2, 128, 4, 16
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (B, S, H, hd))
    kk = jax.random.normal(jax.random.PRNGKey(1), (B, S, H, hd))
    v = jax.random.normal(jax.random.PRNGKey(2), (B, S, H, hd))
    full = L.mha_attention(q, kk, v, mask=L.causal_mask(S))
    for chunk in (16, 32, 64):
        ch = L.chunked_causal_mha(q, kk, v, chunk)
        np.testing.assert_allclose(np.asarray(ch), np.asarray(full),
                                   rtol=1e-5, atol=1e-5)
    # gradients flow through the rematted chunk scan
    g = jax.grad(lambda qq: L.chunked_causal_mha(qq, kk, v, 32).sum())(q)
    assert np.isfinite(np.asarray(g)).all()
