"""Hypothesis with a deterministic fallback.

The property tests prefer real hypothesis (declared in pyproject's ``dev``
extra). On environments where it is not installed, a minimal stand-in runs
each ``@given`` test over a fixed number of deterministically drawn examples
(seeded per test name) so collection — and the properties themselves — still
run on a clean checkout. Only the strategy surface these tests use is
implemented: integers, floats, sampled_from, booleans.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    HAVE_HYPOTHESIS = False

    import functools
    import hashlib
    import inspect
    import random

    _FALLBACK_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

    st = _Strategies()

    def given(*strategies_args):
        def decorate(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hc_max_examples",
                            _FALLBACK_MAX_EXAMPLES)
                seed = int(hashlib.md5(
                    fn.__qualname__.encode()).hexdigest()[:8], 16)
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = [s.draw(rng) for s in strategies_args]
                    fn(*args, *drawn, **kwargs)
            # strategy args fill the TRAILING parameters (hypothesis
            # convention: fixtures first); hide them from pytest's fixture
            # resolution and drop __wrapped__ so inspect doesn't see them.
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())
            fixture_params = params[: len(params) - len(strategies_args)]
            wrapper.__signature__ = sig.replace(parameters=fixture_params)
            del wrapper.__wrapped__
            wrapper._hc_given = True
            return wrapper
        return decorate

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_ignored):
        def decorate(fn):
            if getattr(fn, "_hc_given", False):
                fn._hc_max_examples = min(max_examples,
                                          _FALLBACK_MAX_EXAMPLES)
            return fn
        return decorate
