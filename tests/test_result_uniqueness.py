"""Result-uniqueness invariants: no search path may ever return the same
corpus id twice in a top-k. Regression tests for the sharded padding bug,
where a padded partition row aliased shard row 0's global id and the
all-gather merge could count one item as two results (inflating recall).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SearchConfig, build_engine, mlp_measure
from repro.core.sharded import build_sharded_index, merge_topk


def _assert_unique_rows(ids: np.ndarray):
    for q, row in enumerate(ids):
        real = row[row >= 0]
        assert len(set(real.tolist())) == real.size, \
            f"query {q} returned duplicate ids: {row}"


def test_engine_topk_is_duplicate_free(rng):
    from repro.graph import build_l2_graph
    base = rng.normal(size=(600, 12)).astype(np.float32)
    queries = rng.normal(size=(24, 12)).astype(np.float32)
    g = build_l2_graph(base, m=8, k_construction=24)
    measure = mlp_measure(jax.random.PRNGKey(0), 12, 12, hidden=(32,))
    for mode in ("guitar", "sl2g"):
        eng = build_engine(measure, SearchConfig(k=10, ef=32, mode=mode))
        res = eng.search(measure.params, jnp.asarray(base),
                         jnp.asarray(g.neighbors), jnp.asarray(queries),
                         jnp.full((24,), g.entry, jnp.int32))
        _assert_unique_rows(np.asarray(res.ids))


def test_sharded_index_padding_masks_global_ids(rng):
    base = rng.normal(size=(1030, 12)).astype(np.float32)  # 1030 % 4 == 2
    idx = build_sharded_index(base, n_shards=4, m=8, k_construction=24)
    gids = idx.global_ids
    assert (gids < 0).sum() == 4 * 258 - 1030  # exactly the padded rows
    real = gids[gids >= 0]
    assert np.sort(real).tolist() == list(range(1030))  # disjoint cover
    # padded rows still carry real vectors (row 0 repeats) so graph build
    # and search stay well-defined
    assert np.isfinite(idx.base).all()


def test_merge_topk_drops_padding_and_negatives():
    # shard 1's first candidate is a padding alias (id -1) with the best
    # score of all: pre-fix it would have claimed the top slot
    all_ids = jnp.asarray([[[3, 5, 7], [-1, 6, 9]]])        # (1, 2, 3)
    all_scores = jnp.asarray([[[1.0, 0.5, 0.1], [99.0, 0.4, 0.3]]])
    ids, scores = merge_topk(all_ids, all_scores, 4)
    assert np.asarray(ids[0]).tolist() == [3, 5, 6, 9]
    np.testing.assert_allclose(np.asarray(scores[0]), [1.0, 0.5, 0.4, 0.3])


def test_merge_topk_pads_with_minus_one_when_short():
    all_ids = jnp.asarray([[[2, -1, -1]]])
    all_scores = jnp.asarray([[[0.7, 5.0, 5.0]]])
    ids, scores = merge_topk(all_ids, all_scores, 3)
    assert np.asarray(ids[0]).tolist() == [2, -1, -1]
    assert np.isneginf(np.asarray(scores[0, 1:])).all()


def test_sharded_search_duplicate_free_under_padding(rng):
    """End-to-end on one host: per-shard engine searches + merge_topk (the
    exact code path local_search runs after all_gather) must be
    duplicate-free even though padded rows alias shard row 0's vector."""
    n, dim, S, k = 1030, 12, 4, 10
    base = rng.normal(size=(n, dim)).astype(np.float32)
    queries = rng.normal(size=(16, dim)).astype(np.float32)
    idx = build_sharded_index(base, n_shards=S, m=8, k_construction=24)
    measure = mlp_measure(jax.random.PRNGKey(1), dim, dim, hidden=(32,))
    eng = build_engine(measure, SearchConfig(k=k, ef=32, mode="guitar"))
    per_ids, per_scores = [], []
    for s in range(S):
        res = eng.search(measure.params, jnp.asarray(idx.base[s]),
                         jnp.asarray(idx.neighbors[s]), jnp.asarray(queries),
                         jnp.full((16,), int(idx.entries[s]), jnp.int32))
        gids = jnp.asarray(idx.global_ids[s])
        per_ids.append(jnp.where(res.ids >= 0,
                                 gids[jnp.maximum(res.ids, 0)], -1))
        per_scores.append(res.scores)
    ids, scores = merge_topk(jnp.stack(per_ids, 1), jnp.stack(per_scores, 1),
                             k)
    ids = np.asarray(ids)
    _assert_unique_rows(ids)
    assert (ids >= 0).all()     # plenty of real candidates for k=10
    assert (ids < n).all()
