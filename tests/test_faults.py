"""Fault-domain serving: the injection harness, pager degradation ladder,
circuit-breaker shard health, partial-failure merges, load shedding, and
graceful drain (DESIGN.md §12).

House invariant, extended to failure: a completion is either flagged
(partial / shed / failed / timeout) or BIT-IDENTICAL to the fault-free
run — degraded operation may lose coverage, never correctness.
"""
import sys
from collections import Counter
from pathlib import Path

import jax
import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.core import (EngineOptions, SearchConfig, build_engine,
                        mlp_measure)
from repro.core.corpus import (CorpusUnavailableError, ResidencyPolicy,
                               make_corpus_store)
from repro.core.sharded import build_sharded_index, empty_topk, merge_topk
from repro.ft.straggler import CircuitBreaker
from repro.serving import (ContinuousRuntime, FaultEvent, FaultPlan,
                           InjectedFault, Request, ShardedContinuousRuntime,
                           ShardHealthTracker)
from repro.graph import build_l2_graph


@pytest.fixture(scope="module")
def system():
    rng = np.random.default_rng(0)
    base = rng.normal(size=(600, 16)).astype(np.float32)
    queries = rng.normal(size=(24, 16)).astype(np.float32)
    graph = build_l2_graph(base, m=8, k_construction=24)
    measure = mlp_measure(jax.random.PRNGKey(1), 16, 16, hidden=(32,))
    cfg = SearchConfig(k=5, ef=24, mode="guitar", budget=6, alpha=1.1)
    engine = build_engine(measure, cfg,
                          EngineOptions(rank_impl="ref", measure_impl="vmap"))
    sharded = build_sharded_index(base, n_shards=2, m=8, k_construction=24)
    return dict(base=base, queries=queries, graph=graph, measure=measure,
                cfg=cfg, engine=engine, sharded=sharded)


def _drive(rt, queries, per_round=2):
    """Paced deterministic driver: submit ``per_round`` requests per
    scheduler round (unlike run_stream(realtime=False), which queues the
    whole stream up front — uninteresting for outage dynamics)."""
    i = 0
    done = []
    while i < len(queries) or rt.in_flight or rt.queued or rt._partial \
            or any(r.completions for r in rt.runtimes):
        for _ in range(per_round):
            if i < len(queries):
                rt.submit(queries[i], rid=i)
                i += 1
        done += rt.step_once()
    return {c.rid: c for c in done}


# ---------------------------------------------------------------------------
# the harness itself
# ---------------------------------------------------------------------------

def test_fault_plan_deterministic_and_json_round_trip(tmp_path):
    events = [FaultEvent("shard_crash", site="shard:0/tick", start=3,
                         count=2),
              FaultEvent("page_io_error", site="pager", start=0, count=100,
                         rate=0.3),
              FaultEvent("slow_tick", seconds=0.5)]
    p1 = FaultPlan(events, seed=7)
    path = p1.save(str(tmp_path / "plan.json"))
    p2 = FaultPlan.load(path)
    assert p2.to_dict() == p1.to_dict()
    # same plan, same site, same invocation sequence -> same firings
    a1 = p1.arm("pager", ("page_io_error",))
    a2 = p2.arm("pager", ("page_io_error",))
    fires1 = [a1.next() is not None for _ in range(200)]
    fires2 = [a2.next() is not None for _ in range(200)]
    assert fires1 == fires2
    assert 20 < sum(fires1) < 60      # rate=0.3 over the 100-wide window

    # windows are exact when rate=1
    tick = p1.tick_hook("shard:0/tick")
    got = []
    for i in range(8):
        try:
            tick()
            got.append(False)
        except InjectedFault:
            got.append(True)
    assert got == [False] * 3 + [True] * 2 + [False] * 3


def test_fault_plan_rejects_bad_events():
    with pytest.raises(ValueError):
        FaultEvent("meteor_strike")
    with pytest.raises(ValueError):
        FaultEvent("kill", rate=1.5)


def test_kill_hook_counts_per_stage():
    plan = FaultPlan([FaultEvent("kill", site="mutate/post-journal",
                                 start=1)])
    hook = plan.kill_hook()
    hook("pre-journal")          # different site: never fires
    hook("post-journal")         # idx 0: before the window
    with pytest.raises(InjectedFault):
        hook("post-journal")     # idx 1: fires
    hook("post-journal")         # idx 2: past the window


# ---------------------------------------------------------------------------
# circuit breaker + shard health state machine
# ---------------------------------------------------------------------------

def test_circuit_breaker_state_machine():
    b = CircuitBreaker(k_failures=3, cooldown=2)
    assert not b.record_failure() and not b.record_failure()
    assert b.state == CircuitBreaker.CLOSED and b.serving
    assert b.record_failure()            # 3rd consecutive strike trips it
    assert b.state == CircuitBreaker.OPEN and not b.serving
    b.tick()
    assert b.state == CircuitBreaker.OPEN
    b.tick()
    assert b.state == CircuitBreaker.HALF_OPEN and b.serving
    assert b.record_failure()            # half-open failure reopens at once
    assert b.state == CircuitBreaker.OPEN
    b.tick(); b.tick()
    b.record_success()
    assert b.state == CircuitBreaker.CLOSED and b.failures == 0


def test_shard_health_idle_probe_does_not_close():
    h = ShardHealthTracker(2, k_failures=1, cooldown_rounds=1)
    assert h.record_failure(1, "boom")
    assert h.states() == ["healthy", "open"]
    h.on_round()
    assert h.states() == ["healthy", "half-open"]
    h.record_success(1, probed=False)    # idle tick: no evidence
    assert h.states() == ["healthy", "half-open"]
    h.record_success(1, probed=True)     # real work: re-admitted
    assert h.states() == ["healthy", "healthy"]


# ---------------------------------------------------------------------------
# pager degradation ladder: retry -> whole fallback -> unavailable
# ---------------------------------------------------------------------------

def _paged(base, **policy_kw):
    policy = ResidencyPolicy("paged", page_rows=64, cache_bytes=1 << 20,
                             retry_backoff_s=0.0, **policy_kw)
    return make_corpus_store(base, "float32", residency=policy)


def test_pager_retries_absorb_transient_errors(system):
    base = system["base"]
    whole = make_corpus_store(base, "float32")
    store = _paged(base)
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=1,
                                 count=2)])
    store.set_read_hook(plan.pager_hook())
    ids = np.array([[0, 70, 130], [599, 3, 64]])
    got = np.asarray(store.take(ids))
    np.testing.assert_array_equal(got, np.asarray(whole.take(ids)))
    st = store.stats_snapshot()
    assert st.io_errors == 2 and st.retries >= 2
    assert st.fallback == ""             # never degraded


def test_pager_falls_back_to_whole_and_stays_bit_identical(system):
    base = system["base"]
    whole = make_corpus_store(base, "float32")
    store = _paged(base)
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=0,
                                 count=10 ** 6)])
    store.set_read_hook(plan.pager_hook())
    ids = np.arange(0, 600, 7)
    got = np.asarray(store.take(ids))
    np.testing.assert_array_equal(got, np.asarray(whole.take(ids)))
    st = store.stats_snapshot()
    assert st.fallback == "whole"
    assert st.resident_bytes == base.nbytes
    # degraded mode serves every further gather without touching the hook
    errs_before = st.io_errors
    got2 = np.asarray(store.take(ids[::2]))
    np.testing.assert_array_equal(got2, np.asarray(whole.take(ids[::2])))
    assert store.stats_snapshot().io_errors == errs_before


def test_pager_unavailable_when_fallback_exceeds_budget(system):
    store = _paged(system["base"], fallback_bytes=128)
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=0,
                                 count=10 ** 6)])
    store.set_read_hook(plan.pager_hook())
    with pytest.raises(CorpusUnavailableError):
        store.cache.gather(np.array([5]))


def test_pager_unavailable_when_whole_read_also_fails(system):
    store = _paged(system["base"])
    plan = FaultPlan([FaultEvent("page_io_error", site="pager", start=0,
                                 count=10 ** 6),
                      FaultEvent("page_io_error", site="pager/whole",
                                 start=0, count=10 ** 6)])
    store.set_read_hook(plan.pager_hook())
    with pytest.raises(CorpusUnavailableError):
        store.cache.gather(np.array([5]))


# ---------------------------------------------------------------------------
# sharded partial failure + recovery
# ---------------------------------------------------------------------------

def test_sharded_one_shard_down_partial_and_recovers(system):
    s = system
    qs = np.random.default_rng(3).normal(size=(48, 16)).astype(np.float32)
    ref_rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=4,
        query_dim=16, steps_per_tick=2)
    ref = _drive(ref_rt, qs)

    plan = FaultPlan([FaultEvent("shard_crash", site="shard:1/tick",
                                 start=4, count=3)], seed=0)
    rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=4,
        query_dim=16, steps_per_tick=2, k_failures=3, cooldown_rounds=4,
        fault_plan=plan)
    got = _drive(rt, qs)

    assert set(got) == set(range(48))          # every rid resolves once
    statuses = Counter(c.status for c in got.values())
    assert statuses["partial"] > 0 and statuses["ok"] > 0
    assert rt.health.n_opened >= 1
    assert rt.health.states() == ["healthy", "healthy"]   # re-admitted
    for rid, c in got.items():
        if c.status == "ok":                   # unflagged => bit-identical
            np.testing.assert_array_equal(c.ids, ref[rid].ids)
            np.testing.assert_array_equal(c.scores, ref[rid].scores)
        else:
            assert c.status == "partial" and c.partial
            assert c.record.partial
            assert (c.ids >= 0).any()          # survivors still answered
    m = rt.metrics.summary()
    assert m["n_partial"] == statuses["partial"]


def test_sharded_all_shards_down_empty_harvest(system):
    s = system
    plan = FaultPlan([FaultEvent("shard_crash", site="shard:0/tick",
                                 start=0, count=50),
                      FaultEvent("shard_crash", site="shard:1/tick",
                                 start=0, count=50)])
    rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=4,
        query_dim=16, steps_per_tick=2, k_failures=1, cooldown_rounds=100,
        fault_plan=plan)
    got = _drive(rt, s["queries"][:4])
    assert len(got) == 4                       # resolves instead of hanging
    for c in got.values():
        assert c.status == "failed" and c.record.failed
        assert (c.ids == -1).all() and (c.scores == -np.inf).all()
    assert rt.metrics.summary()["n_failed"] == 4


def test_merge_topk_all_invalid_window(system):
    ids = np.full((1, 2, 5), -1, np.int32)
    scores = np.random.default_rng(0).normal(size=(1, 2, 5)) \
        .astype(np.float32)                    # scores of invalid ids ignored
    m_ids, m_scores = merge_topk(ids, scores, k=5)
    e_ids, e_scores = empty_topk(5)
    np.testing.assert_array_equal(np.asarray(m_ids)[0], e_ids)
    np.testing.assert_array_equal(np.asarray(m_scores)[0], e_scores)


# ---------------------------------------------------------------------------
# load shedding + graceful drain
# ---------------------------------------------------------------------------

def test_bounded_queue_sheds(system):
    s = system
    rt = ContinuousRuntime(s["engine"], s["measure"].params, s["base"],
                           s["graph"].neighbors, n_lanes=2, query_dim=16,
                           entry=s["graph"].entry, steps_per_tick=2,
                           max_queue=2)
    shed_rids = []
    for i in range(6):                         # 2 queue slots, 4 over
        rt.submit(s["queries"][i], rid=i)
    comps = {c.rid: c for c in rt.pop_completions()}
    assert len(comps) == 4
    for c in comps.values():
        assert c.status == "shed" and c.record.shed
        assert (c.ids == -1).all()
    while rt.queue or rt.in_flight:
        rt.step_once()
    done = {c.rid: c for c in rt.pop_completions()}
    assert sum(c.status == "ok" for c in done.values()) == 2
    m = rt.metrics.summary()
    assert m["n_shed"] == 4 and m["n_completed"] == 2
    assert m["queue_depth_max"] == 2


def test_close_drains_gracefully(system):
    s = system
    rt = ContinuousRuntime(s["engine"], s["measure"].params, s["base"],
                           s["graph"].neighbors, n_lanes=2, query_dim=16,
                           entry=s["graph"].entry, steps_per_tick=2)
    for i in range(5):
        rt.submit(s["queries"][i], rid=i)
    rt.step_once()
    assert rt.in_flight == 2                   # lanes filled, rest queued
    rt.close()
    assert rt.in_flight == 0 and not rt.queue
    done = {c.rid: c for c in rt.pop_completions()}
    assert set(done) == set(range(5))          # every rid resolved once
    statuses = Counter(c.status for c in done.values())
    assert statuses["ok"] >= 2                 # in-flight lanes finished
    assert statuses["ok"] + statuses["shed"] == 5
    assert rt.submit(s["queries"][0], rid=99) == 99
    assert rt.pop_completions()[-1].status == "shed"   # admits nothing new


def test_sharded_shed_and_close(system):
    s = system
    rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=2,
        query_dim=16, steps_per_tick=2, max_queue=2)
    for i in range(6):
        rt.submit(s["queries"][i], rid=i)
    done = {c.rid: c for c in rt.pop_completions()}
    assert sum(c.status == "shed" for c in done.values()) == 4
    while len(done) < 6:                       # the 2 admitted finish ok
        for c in rt.step_once():
            done[c.rid] = c
    assert set(done) == set(range(6))
    assert Counter(c.status for c in done.values()) \
        == Counter({"shed": 4, "ok": 2})
    rt.pop_completions()
    rt.close()
    assert rt.submit(s["queries"][0], rid=99) == 99    # late submit: shed
    assert rt.pop_completions()[-1].status == "shed"
    assert rt.metrics.summary()["n_shed"] == 5


def test_health_line_mentions_shard_states(system):
    s = system
    rt = ShardedContinuousRuntime(
        s["engine"], s["measure"].params, s["sharded"], n_lanes=2,
        query_dim=16)
    line = rt.format_health()
    assert "shards=[healthy,healthy]" in line and "shed=0" in line
