"""Distribution correctness on 8 fake host devices — run in a subprocess so
XLA_FLAGS can force the device count before jax initializes (the rest of the
suite must keep seeing one device)."""
import subprocess
import sys
import os

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, "src")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

mesh = jax.make_mesh((2, 4), ("data", "model"))

# ---- 1. MoE: EP (shard_map all-to-all) == scatter (pjit) == local ---------
from repro.models import moe as moe_lib
from repro.sharding import mesh_rules, single_device_rules

key = jax.random.PRNGKey(0)
d, ff, E, K = 16, 32, 8, 2
p, _ = moe_lib.init_moe(key, n_layers=1, d_model=d, d_ff=ff, n_experts=E,
                        dtype=jnp.float32)
lp = jax.tree_util.tree_map(lambda a: a[0], p)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, d))

local = moe_lib.moe_ffn(lp, x, n_experts=E, top_k=K, capacity_factor=100.0,
                        n_groups=1)
rules = mesh_rules(mesh)
with mesh:
    ep = jax.jit(lambda lp, x: moe_lib.moe_ffn_ep(
        lp, x, n_experts=E, top_k=K, capacity_factor=100.0,
        rules=rules))(lp, x)
err = float(jnp.abs(local - ep).max())
assert err < 2e-4, f"EP vs local mismatch {err}"
print("moe ep==local OK", err)

# ---- 2. LM train step: sharded loss == single-device loss -----------------
from repro.models import transformer as tf_lib
from repro.sharding import shardings_for_tree

cfg = tf_lib.TransformerConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                               d_ff=64, vocab_size=128, head_dim=8,
                               dtype=jnp.float32, remat=False)
params, axes = tf_lib.init_params(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 128)
loss_local = tf_lib.lm_loss(params, toks, toks, cfg)
with mesh:
    psh = shardings_for_tree(axes, mesh, rules)
    loss_sharded = jax.jit(
        lambda p, t: tf_lib.lm_loss(p, t, t, cfg, rules),
        in_shardings=(psh, NamedSharding(mesh, P("data", None))),
    )(params, toks)
err = abs(float(loss_local) - float(loss_sharded))
assert err < 2e-3, f"sharded loss mismatch {err}"
print("sharded lm loss OK", err)

# ---- 3. Corpus-sharded GUITAR search == single search ---------------------
from repro.core import SearchConfig, mlp_measure, brute_force_topk, recall
from repro.core.sharded import build_sharded_index, sharded_search_host

rng = np.random.default_rng(0)
# 1030 % 4 != 0 -> partitions are padded; padded rows alias shard row 0's
# vector but must never alias its global id in the merged top-k
base = rng.normal(size=(1030, 12)).astype(np.float32)
queries = rng.normal(size=(8, 12)).astype(np.float32)
measure = mlp_measure(jax.random.PRNGKey(2), 12, 12, hidden=(32,))
true_ids, _ = brute_force_topk(measure, jnp.asarray(base), jnp.asarray(queries), 5)
idx = build_sharded_index(base, n_shards=4, m=8, k_construction=24)
assert (idx.global_ids < 0).sum() == 4 * 258 - 1030
cfg = SearchConfig(k=5, ef=32, mode="guitar", budget=6, alpha=1.1)
sres = sharded_search_host(measure, idx, queries, cfg, mesh)
ids, scores = sres.ids, sres.scores
assert sres.n_eval.shape == (8,) and (sres.n_eval >= 4).all()
assert (sres.n_iters >= 1).all()
for row in np.asarray(ids):
    real = row[row >= 0]
    assert len(set(real.tolist())) == real.size, f"duplicate ids in {row}"
r = recall(jnp.asarray(ids), true_ids)
assert r > 0.6, f"sharded search recall {r}"
print("sharded search OK recall", r, "duplicate-free")

# ---- 3b. continuous sharded runtime == one-shot sharded merge -------------
# (per-shard lane recycling + merged harvest must be result-identical to the
# shard_map all-gather merge, counters included)
from repro.core import EngineOptions, build_engine
from repro.serving import Request, ShardedContinuousRuntime

eng = build_engine(measure, cfg, EngineOptions())
rt = ShardedContinuousRuntime(eng, measure.params, idx, n_lanes=3,
                              query_dim=12, steps_per_tick=2)
order = np.random.default_rng(1).permutation(8)
comps = rt.run_stream([Request(rid=int(i), query=queries[i]) for i in order],
                      realtime=False)
by = {c.rid: c for c in comps}
for i in range(8):
    assert np.array_equal(by[i].ids, np.asarray(ids)[i]), i
    assert np.array_equal(by[i].scores, np.asarray(scores)[i]), i
    assert by[i].n_eval == int(sres.n_eval[i]), i
    assert by[i].n_iters == int(sres.n_iters[i]), i
print("continuous sharded == oneshot sharded OK")

# ---- 4. gradient compression across pod axis (simulated) ------------------
from repro.train import compress
g = {"w": jax.random.normal(jax.random.PRNGKey(3), (64,))}
e = compress.init_error_state(g)
c, e2 = compress.compress_int8_ef(g, e)
back = compress.decompress_int8(c)
assert float(jnp.abs(back["w"] - g["w"]).max()) < 0.05
print("compression OK")
print("ALL DISTRIBUTED OK")
"""


@pytest.mark.slow
def test_distributed_8dev():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr[-3000:]}"
    assert "ALL DISTRIBUTED OK" in out.stdout
