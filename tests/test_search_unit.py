"""Unit-level searcher invariants + hypothesis properties (fast — tiny
corpora, cheap measures)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (SearchConfig, brute_force_topk, inner_product_measure,
                        l2_measure, recall, search_measure)
from repro.core.search import _bit_set, _bit_test, rank_and_prune
from repro.graph import build_l2_graph


@pytest.fixture(scope="module")
def small_corpus(rng=np.random.default_rng(7)):
    base = rng.normal(size=(600, 12)).astype(np.float32)
    queries = rng.normal(size=(12, 12)).astype(np.float32)
    graph = build_l2_graph(base, m=10, k_construction=32)
    return base, queries, graph


def test_bitmap_roundtrip():
    bm = jnp.zeros((4,), jnp.uint32)
    ids = jnp.asarray([0, 31, 32, 100, 127])
    bm = _bit_set(bm, ids, jnp.ones(5, bool))
    assert bool(_bit_test(bm, jnp.asarray([31]))[0])
    assert bool(_bit_test(bm, jnp.asarray([100]))[0])
    assert not bool(_bit_test(bm, jnp.asarray([99]))[0])


def test_l2_measure_search_matches_knn(small_corpus):
    """With the l2 measure, graph search == approximate nearest neighbors."""
    base, queries, graph = small_corpus
    m = l2_measure()
    true_ids, _ = brute_force_topk(m, jnp.asarray(base), jnp.asarray(queries), 5)
    cfg = SearchConfig(k=5, ef=48, mode="sl2g")
    res = search_measure(m, jnp.asarray(base), jnp.asarray(graph.neighbors),
                         jnp.asarray(queries),
                         jnp.full((12,), graph.entry, jnp.int32), cfg)
    assert recall(res.ids, true_ids) > 0.9


def test_mips_measure_search(small_corpus):
    base, queries, graph = small_corpus
    m = inner_product_measure()
    true_ids, _ = brute_force_topk(m, jnp.asarray(base), jnp.asarray(queries), 5)
    cfg = SearchConfig(k=5, ef=48, mode="guitar", budget=6, alpha=1.1)
    res = search_measure(m, jnp.asarray(base), jnp.asarray(graph.neighbors),
                         jnp.asarray(queries),
                         jnp.full((12,), graph.entry, jnp.int32), cfg)
    assert recall(res.ids, true_ids) > 0.6


def test_budget_bounds_evals(small_corpus):
    base, queries, graph = small_corpus
    m = l2_measure()
    for budget in (2, 4, 8):
        cfg = SearchConfig(k=5, ef=32, mode="guitar", budget=budget,
                           alpha=10.0, max_iters=50)
        res = search_measure(m, jnp.asarray(base), jnp.asarray(graph.neighbors),
                             jnp.asarray(queries),
                             jnp.full((12,), graph.entry, jnp.int32), cfg)
        max_evals = 1 + budget * np.asarray(res.n_iters)
        assert (np.asarray(res.n_eval) <= max_evals + 1).all()


def test_guitar_evals_less_than_sl2g(small_corpus):
    base, queries, graph = small_corpus
    m = l2_measure()
    args = (m, jnp.asarray(base), jnp.asarray(graph.neighbors),
            jnp.asarray(queries), jnp.full((12,), graph.entry, jnp.int32))
    res_s = search_measure(*args, SearchConfig(k=5, ef=32, mode="sl2g"))
    res_g = search_measure(*args, SearchConfig(k=5, ef=32, mode="guitar",
                                               budget=6))
    assert float(res_g.n_eval.mean()) < 0.7 * float(res_s.n_eval.mean())


@settings(max_examples=30, deadline=None)
@given(st.integers(3, 32), st.integers(4, 24), st.floats(1.0, 4.0),
       st.sampled_from(["angle", "projection"]))
def test_rank_and_prune_invariants(b, d, alpha, rank_by):
    key = jax.random.PRNGKey(b * d)
    diffs = jax.random.normal(key, (b, d))
    grad = jax.random.normal(jax.random.PRNGKey(1), (d,)) + 0.01
    valid = jax.random.bernoulli(jax.random.PRNGKey(2), 0.8, (b,))
    valid = valid.at[0].set(True)
    C = min(5, b)
    sel_idx, sel_mask = rank_and_prune(diffs, grad, valid, C, alpha, rank_by,
                                       adaptive=True)
    assert sel_idx.shape == (C,) and sel_mask.shape == (C,)
    # masked-in selections must be valid neighbors
    v = np.asarray(valid)
    for i, m in zip(np.asarray(sel_idx), np.asarray(sel_mask)):
        if m:
            assert v[i]
    # the single best neighbor always survives
    assert bool(sel_mask[0]), "top-ranked neighbor must be selected"


def test_entry_always_in_results_when_best():
    """Degenerate: base point identical to query argmax must be found."""
    base = np.zeros((10, 4), np.float32)
    base[7] = 1.0
    nbrs = np.full((10, 3), -1, np.int32)
    for i in range(10):
        nbrs[i] = [(i + 1) % 10, (i + 2) % 10, (i + 5) % 10]
    m = inner_product_measure()
    q = np.ones((1, 4), np.float32)
    cfg = SearchConfig(k=1, ef=8, mode="guitar", budget=3)
    res = search_measure(m, jnp.asarray(base), jnp.asarray(nbrs),
                         jnp.asarray(q), jnp.zeros((1,), jnp.int32), cfg)
    assert int(res.ids[0, 0]) == 7
